"""Multi-tenant gateway quickstart: admission control in front of a tier.

Wraps the paper-scale DES node with ``serving.gateway.Gateway`` via
``build_system(..., gateway=...)`` (DESIGN §3.3) and walks the four
outcomes a production front door must surface:

- **stream**  an admitted request's tokens through the same
  ``RequestHandle`` every tier returns;
- **cancel**  a gateway-queued request before it ever reaches the node;
- **reject**  overflow beyond a tenant's queue cap, with a
  ``retry_after`` hint on the handle;
- **audit**   one ``GatewayDecision`` per submit plus the per-tenant
  ``gateway_stats()`` roll-up.

Runs in a couple of seconds (pure DES, no JAX). Exits non-zero unless
every contract above held (the CI api-smoke pattern).

    PYTHONPATH=src python examples/gateway_multitenant.py
"""
from repro.core import Request, RequestState
from repro.serving import (GatewayConfig, NodeConfig, TenantPolicy,
                           build_system)


def main() -> None:
    system = build_system(
        "chameleon", tier="sim", node=NodeConfig(n_adapters=16),
        gateway=GatewayConfig(
            default_policy=TenantPolicy(weight=1.0, max_inflight=8,
                                        max_queued=64),
            tenants={"bulk": TenantPolicy(weight=0.5, max_inflight=1,
                                          max_queued=3)},
        ))
    print(f"system: {type(system).__name__} wrapping "
          f"{type(system.inner).__name__}")

    # --- stream: tenant-tagged submit, same handle as every tier -----
    streamed = []
    handle = system.submit(
        "acme", Request(input_len=64, output_len=8, adapter_id=0),
        on_token=streamed.append)
    print("streaming req", handle.req_id, "for acme:", end=" ")
    for tok in handle:
        print(tok, end=" ", flush=True)
    print(f" [{handle.state.value}]")
    assert len(streamed) == 8, "expected 8 streamed tokens"
    assert handle.decision.action == "admit", handle.decision

    # --- cancel: a queued request never reaches the node -------------
    victim = system.submit(
        "acme", Request(input_len=64, output_len=32, adapter_id=1))
    assert victim.cancel(), "cancel must succeed while gateway-queued"
    assert victim.state is RequestState.CANCELLED, victim.state
    print(f"cancelled req {victim.req_id} while queued at the gateway")

    # --- reject: the 'bulk' tenant overflows its own queue cap -------
    flood = [system.submit("bulk", Request(input_len=64, output_len=16,
                                           adapter_id=2))
             for _ in range(10)]
    rejected = [h for h in flood if h.state is RequestState.REJECTED]
    print(f"bulk flood: {len(flood) - len(rejected)} admitted, "
          f"{len(rejected)} rejected "
          f"(retry_after={rejected[0].retry_after:.1f}s, "
          f"reason={rejected[0].decision.reason})")
    assert rejected, "queue cap must reject the overflow"
    assert all(h.retry_after > 0 for h in rejected)
    assert all(h.decision.reason == "tenant_queue_full" for h in rejected)

    system.drain()
    survivors = [h for h in flood if h.state is RequestState.FINISHED]
    assert len(survivors) == len(flood) - len(rejected), \
        "every admitted request must reach a terminal state"

    # --- audit: decision per submit + per-tenant roll-up -------------
    gs = system.gateway_stats()
    assert len(system.decisions) == gs["n_submitted"]
    print(f"\ngateway: {gs['n_submitted']} submitted, "
          f"{gs['n_admitted']} admitted, {gs['n_rejected']} rejected")
    for tenant, ts in sorted(gs["tenants"].items()):
        print(f"  {tenant:8s} submitted={ts['submitted']:2d} "
              f"completed={ts['completed']:2d} "
              f"rejected={ts['rejected']:2d} "
              f"tokens={ts['tokens_done']}")
    print("gateway-smoke ok: stream + cancel + reject + audit")


if __name__ == "__main__":
    main()
