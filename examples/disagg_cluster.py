"""Disaggregated prefill/decode serving: long-prefill bursts beside a
live decode stream (DESIGN §3.4).

Builds the ``"disagg"`` tier via ``build_system`` — replicas split
into prefill and decode roles, a paged-KV handoff plane between them —
and drives the scenario disaggregation exists for: a steady decode
stream that keeps producing tokens while bursts of long prompts
prefill *on the other tier*. Prints the handoff statistics (shipments,
bytes, link wait) and the per-role utilization gauges. ~1 minute on
CPU.

    PYTHONPATH=src python examples/disagg_cluster.py

Exits non-zero unless every request completes, at least one KV handoff
actually crossed the link, and a mid-handoff cancellation resolves
cleanly (the CI api-smoke contract).
"""
import numpy as np

from repro.core import Request, RequestState
from repro.serving import build_system
from repro.serving.disagg import DisaggCluster
from repro.serving.engine import EngineConfig


def main() -> None:
    system = build_system(
        "chameleon", tier="disagg", n_nodes=3,
        ecfg=EngineConfig(max_slots=4, max_len=320, n_lora_slots=4,
                          n_adapters=8))
    assert isinstance(system, DisaggCluster)
    print(f"system: {type(system).__name__} "
          f"({len(system.prefill)} prefill + {len(system.decode)} "
          f"decode replicas)")
    system.warmup()

    # --- decode stream: short prompts, long outputs ------------------
    rng = np.random.default_rng(0)
    stream = [system.submit(Request(
        input_len=12, output_len=48, adapter_id=i % 4,
        prompt=[int(x) for x in rng.integers(1, 120, 12)]))
        for i in range(4)]

    # Let the stream hand off to the decode tier and produce a while.
    while any(len(h.tokens) < 8 for h in stream):
        system.step()
    print("stream decoding on the decode tier; migrating now:",
          sum(len(e._migrating) for e in system.engines))

    # --- long-prefill burst: lands on the *prefill* tier -------------
    burst = [system.submit(Request(
        input_len=200, output_len=4, adapter_id=4 + i,
        prompt=[int(x) for x in rng.integers(1, 120, 200)]))
        for i in range(2)]
    print("burst submitted: 2 x 200-token prompts "
          f"-> replicas {[h.node for h in burst]}")

    # --- cancel one stream request mid-flight ------------------------
    victim = stream.pop()
    assert victim.cancel(), "cancel must succeed on a live request"

    system.drain()
    assert victim.state is RequestState.CANCELLED, victim.state
    done = stream + burst
    assert all(h.done and h.state is RequestState.FINISHED
               for h in done), [h.state for h in done]
    assert all(len(h.tokens) == h.req.output_len for h in done)

    # --- what moved where --------------------------------------------
    s = system.stats()
    merged, _ = system.metrics()
    sg = merged.sched_stats
    print(f"handoffs: {s['handoff']['handoffs']} shipments, "
          f"{s['handoff']['handoff_gb']:.6f} GB over the link, "
          f"mean wait {s['handoff']['handoff_wait_s'] * 1e3:.2f} ms")
    print(f"spilled prefills: {s['spilled_prefills']}  "
          f"routed via prefill tier: {s['routed_prefill']}")
    print(f"role utilization: prefill={sg['prefill_util']:.3f} "
          f"decode={sg['decode_util']:.3f}")
    if "role_plan" in s:
        p = s["role_plan"]
        print(f"autoscaler: wants {p['want_prefill']} prefill / "
              f"{p['want_decode']} decode "
              f"(demand {p['prefill_demand_tokens']} vs "
              f"{p['decode_demand_tokens']} tokens)")
    assert s["handoff"]["handoffs"] >= 1, "no KV handoff crossed the link"
    for e in system.engines:
        if e.paged:
            e.pool.check_invariants(free_page_ids=e.free_pages)
    print("ok: all requests completed, tokens streamed across the "
          "prefill->decode handoff")


if __name__ == "__main__":
    main()
