"""Quickstart: serve a small LLM with many LoRA adapters via Chameleon.

Runs the *real* JAX engine (continuous batching + Chameleon adapter
cache + WRS multi-queue scheduler) over a reduced Llama-style model on
whatever device this host has. ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Request
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


def main() -> None:
    cfg = get_config("chameleon-llama-7b").reduced()
    print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d{cfg.d_model})")
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    eng = ChameleonEngine(cfg, params, EngineConfig(
        max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8))

    rng = np.random.default_rng(0)
    reqs = [Request(input_len=int(rng.integers(4, 30)),
                    output_len=int(rng.integers(4, 24)),
                    adapter_id=int(rng.integers(0, 8)))
            for _ in range(16)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()

    print(f"\ncompleted {len(eng.completed)} requests")
    for r in eng.completed[:6]:
        toks = eng.outputs.get(r.req_id, [])
        print(f"  req {r.req_id:3d} adapter={r.adapter_id} "
              f"in={r.input_len:3d} out={r.generated:3d} "
              f"ttft={r.ttft():.3f}s tokens={toks[:8]}...")
    st = eng.stats()
    c = st["cache"]
    print(f"\nadapter cache: {c['hits']} hits / {c['misses']} misses "
          f"/ {c['evictions']} evictions "
          f"(hit rate {c['hits'] / max(c['hits'] + c['misses'], 1):.2f})")
    print(f"resident adapters at drain: {st['resident_adapters']}")
    print(f"scheduler: bypassed={st['bypassed']} squashed={st['squashed']}")


if __name__ == "__main__":
    main()
