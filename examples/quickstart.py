"""Quickstart: serve a small LLM with many LoRA adapters via Chameleon.

Drives the *real* JAX engine (continuous batching + Chameleon adapter
cache + WRS multi-queue scheduler) through the unified serving surface
(DESIGN §3): ``build_system`` assembles the tier, ``submit`` returns a
``RequestHandle`` that streams tokens, carries the lifecycle state
machine, and supports ``cancel()`` and per-request ``SamplingParams``.
~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

The same four verbs drive every tier — swap ``tier="engine"`` for
``"sim"`` (paper-scale DES) or ``"cluster"`` (N replicas, one router).
Exits non-zero unless at least one token streamed and one cancellation
completed cleanly (the CI api-smoke contract).
"""
import numpy as np

from repro.core import Request, RequestState, SamplingParams
from repro.serving import build_system
from repro.serving.engine import EngineConfig


def main() -> None:
    system = build_system(
        "chameleon", tier="engine",
        ecfg=EngineConfig(max_slots=4, max_len=128, n_lora_slots=4,
                          n_adapters=8))
    print(f"system: {type(system).__name__} (unified serving surface)")

    # --- streaming: iterate a handle; the engine is pumped for you ---
    streamed = []
    handle = system.submit(
        Request(input_len=12, output_len=8, adapter_id=0,
                prompt=list(range(100, 112))),
        on_token=streamed.append)
    print("streaming req", handle.req_id, "tokens:", end=" ", flush=True)
    for tok in handle:
        print(tok, end=" ", flush=True)
    print(f"  [{handle.state.value}]")
    assert len(streamed) == 8, "expected 8 streamed tokens"

    # --- sampling: per-request temperature/top-k with a seed ---------
    sampled = system.submit(
        Request(input_len=12, output_len=8, adapter_id=1),
        sampling=SamplingParams(temperature=0.8, top_k=20, seed=7),
    ).result()
    print(f"sampled  req tokens={sampled.tokens} "
          f"(T=0.8 top_k=20 seed=7)")

    # --- a small batch + one cancellation ----------------------------
    rng = np.random.default_rng(0)
    handles = [system.submit(Request(
        input_len=int(rng.integers(4, 30)),
        output_len=int(rng.integers(4, 24)),
        adapter_id=int(rng.integers(0, 8)))) for _ in range(14)]
    victim = handles[len(handles) // 2]
    assert victim.cancel(), "cancel must succeed on a live request"
    system.drain()
    assert victim.state is RequestState.CANCELLED, victim.state
    done = [h for h in handles if h.state is RequestState.FINISHED]
    print(f"\ncompleted {len(done)}/{len(handles)} "
          f"(1 cancelled cleanly)")

    for h in done[:5]:
        res = h.result()
        print(f"  req {res.req_id:3d} adapter={res.adapter_id} "
              f"n={res.n_tokens:3d} queue={res.queue_wait:.3f}s "
              f"load={res.adapter_load_wait:.3f}s "
              f"ttft={res.ttft:.3f}s e2e={res.e2e:.3f}s")

    st = system.stats()
    c = st["cache"]
    print(f"\nadapter cache: {c['hits']} hits / {c['misses']} misses "
          f"/ {c['evictions']} evictions")
    print(f"scheduler: bypassed={st['bypassed']} "
          f"squashed={st['squashed']} cancelled={st['cancelled']} "
          f"expired={st['expired']}")
    print("resident adapters at drain:", st["resident_adapters"])
    print("api-smoke ok: streamed tokens + clean cancellation")


if __name__ == "__main__":
    main()
