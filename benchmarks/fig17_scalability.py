"""Figs. 17/18: scalability over model size and memory capacity.

Llama-7B/13B/30B on A100-80G (Fig. 17: normalized P99 + throughput) and
Llama-7B under 24/48/80 GB memory configs (Fig. 18). Paper claims:
Chameleon wins across all sizes (−60 % P99-ish, 1.4–1.9× throughput);
larger memory ⇒ larger win (more room for adapter caching).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving import NodeConfig, build_node, synthesize
from repro.serving.cost_model import A100_80G, HW_PRESETS, MODEL_PRESETS
from repro.serving.trace import TraceConfig

from .common import run_system, ttft_slo

NAME = "fig17_scalability"
PAPER_REF = "Figures 17 and 18"

# Load levels per model size (bigger model = slower node = lower RPS).
LOADS = {"llama-7b": (8.0, 12.0, 16.0), "llama-13b": (4.0, 6.0, 8.0),
         "llama-30b": (1.5, 2.5, 3.5)}
N_ADAPTERS = {"llama-7b": 500, "llama-13b": 100, "llama-30b": 10}


def _mem_hw(gb: float):
    return dataclasses.replace(A100_80G, hbm_gb=gb, name=f"a100-{gb:.0f}g")


def run(quick: bool = False):
    duration = 45.0 if quick else 120.0
    rows = []
    # --- Fig 17: model sizes on A100-80G ---
    for model in ("llama-7b", "llama-13b", "llama-30b"):
        loads = LOADS[model][:2] if quick else LOADS[model]
        for level, rps in zip(("low", "med", "high"), loads):
            out = {}
            for system in ("slora", "chameleon"):
                m, *_ = run_system(
                    system, rps, duration=duration,
                    node_kw={"hw": "a100-80g", "model": model,
                             "n_adapters": N_ADAPTERS[model]})
                out[system] = m
            rows.append({
                "figure": "17", "model": model, "load": level, "rps": rps,
                "p99_norm": out["chameleon"].p99_ttft()
                    / max(out["slora"].p99_ttft(), 1e-9),
                "goodput_ratio": out["chameleon"].goodput_tokens_per_s()
                    / max(out["slora"].goodput_tokens_per_s(), 1e-9),
            })
    # --- Fig 18: memory capacities, llama-7b ---
    import repro.serving.systems as sysmod
    for gb in (24.0, 48.0, 80.0):
        hw = _mem_hw(gb)
        sysmod.HW_PRESETS[hw.name] = hw
        HW_PRESETS[hw.name] = hw
        out = {}
        for system in ("slora", "chameleon"):
            m, *_ = run_system(system, 10.0, duration=duration,
                               node_kw={"hw": hw.name, "model": "llama-7b",
                                        "n_adapters": 500})
            out[system] = m
        rows.append({
            "figure": "18", "hbm_gb": gb,
            "p99_norm": out["chameleon"].p99_ttft()
                / max(out["slora"].p99_ttft(), 1e-9),
            "hit_gain": out["chameleon"].cache_stats["hit_rate"]
                - out["slora"].cache_stats["hit_rate"],
        })
    return rows


def validate(rows) -> dict:
    f17 = [r for r in rows if r["figure"] == "17"]
    f18 = sorted((r for r in rows if r["figure"] == "18"),
                 key=lambda r: r["hbm_gb"])
    wins = sum(1 for r in f17 if r["p99_norm"] < 1.0)
    return {
        "chameleon_wins_fraction": round(wins / max(len(f17), 1), 2),
        "p99_norm_by_model": {
            m: round(float(np.mean([r["p99_norm"] for r in f17
                                    if r["model"] == m])), 3)
            for m in ("llama-7b", "llama-13b", "llama-30b")},
        "bigger_memory_bigger_win":
            f18[-1]["p99_norm"] <= f18[0]["p99_norm"] + 0.05,
        "p99_norm_by_mem": {r["hbm_gb"]: round(r["p99_norm"], 3)
                            for r in f18},
    }


def _pick_mesh_shape() -> tuple:
    """Largest parity-grid mesh the host's devices support — CI runs
    with XLA_FLAGS=--xla_force_host_platform_device_count=4 so the
    full (2,2) data×model mesh is exercised there."""
    import jax
    n = len(jax.devices())
    for shape in ((2, 2), (1, 2), (2, 1), (1, 1)):
        if shape[0] * shape[1] <= n:
            return shape
    return (1, 1)


def run_real_engine(n_requests: int = 24, seed: int = 0,
                    quick: bool = False,
                    mesh_shape: tuple | None = None) -> list[dict]:
    """Scalability measured on the *real* engine: one ChameleonEngine
    across N devices vs the same engine single-device.

    The fig10-style paged workload (shared-prefix-heavy, multi-adapter,
    mixed greedy/sampled) runs with the full serving data plane on —
    paged KV, fused hot loop, prefix cache — first with
    ``mesh_shape=None``, then sharded. The only variable is the mesh:
    DESIGN §4's exact-reductions mode makes the sharded arm
    token-identical, asserted per request by submission order.
    ``MemoryPool.check_invariants()`` runs after every engine step.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import Request, SamplingParams
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig

    cfg = get_config("chameleon-llama-7b").reduced()
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    if quick:
        n_requests = min(n_requests, 12)
    mesh_shape = mesh_shape or _pick_mesh_shape()

    # Shared-prefix-heavy multi-adapter trace (the prefix cache must
    # have something to hit) with real token ids; every 3rd request
    # samples stochastically so the sharded sampler is exercised too.
    rng = np.random.default_rng(seed)
    pres = [rng.integers(3, 256, size=40).tolist() for _ in range(2)]
    specs = []
    for i in range(n_requests):
        prompt = (pres[i % 2]
                  + rng.integers(3, 256,
                                 size=int(rng.integers(4, 13))).tolist())
        specs.append((prompt, int(rng.integers(8, 24)),
                      int(rng.integers(0, 8)),
                      SamplingParams(temperature=0.8, top_k=8, seed=i)
                      if i % 3 == 2 else None))

    rows = []
    tokens_by_mode = {}
    for mode, ms in (("single", None), ("mesh", mesh_shape)):
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8,
            seed=seed, paged=True, fused_hotloop=True,
            prefix_cache=True, async_load=False,
            queued_prefetch=False, histogram_prefetch=False,
            mesh_shape=ms))
        handles = [eng.submit(Request(input_len=len(p), output_len=o,
                                      adapter_id=a, prompt=list(p)),
                              sampling=sp)
                   for p, o, a, sp in specs]
        t0 = time.perf_counter()
        steps = 0
        while eng.busy() and steps < 50_000:
            eng.step()
            eng.pool.check_invariants(
                free_page_ids=getattr(eng, "free_pages", None))
            steps += 1
        wall = time.perf_counter() - t0
        # req_ids are globally monotonic across engine instances:
        # compare by submission order via the handles.
        streamed = [h.tokens for h in handles]
        tokens_by_mode[mode] = streamed
        n_tok = sum(len(t) for t in streamed)
        ss = eng.shard_stats()
        rows.append({
            "mode": mode,
            "mesh_shape": "x".join(map(str, ms)) if ms else "none",
            "n_devices": ss.get("n_devices", 1),
            "submitted": n_requests,
            "completed": len(eng.completed),
            "steps": steps,
            "wall_s": round(wall, 3),
            "tokens_per_s": round(n_tok / max(wall, 1e-9), 1),
            "prefix_hit_rate": eng.stats()["prefix_hit_rate"],
            "tokens_identical_to_single":
                tokens_by_mode["single"] == streamed,
            "collective_frac": ss.get("collective_frac", 0.0),
            "collective_dispatches": ss.get("collective_dispatches", 0),
            "per_shard_pages_used": ss.get("per_shard_pages_used", []),
            "per_shard_lora_slot_bytes":
                ss.get("per_shard_lora_slot_bytes", 0),
        })
    return rows


def validate_real_engine(rows) -> dict:
    single = next(r for r in rows if r["mode"] == "single")
    mesh = next(r for r in rows if r["mode"] == "mesh")
    return {
        # Both arms must fully drain — equal truncation is not success.
        "all_completed":
            single["completed"] == single["submitted"]
            and mesh["completed"] == mesh["submitted"],
        # The acceptance claim (DESIGN §4): the sharded data plane is
        # bit-token-identical to single-device, greedy and sampled,
        # with fused hot loop + prefix cache + paged KV all enabled.
        "tokens_identical": bool(mesh["tokens_identical_to_single"]),
        "mesh_shape": mesh["mesh_shape"],
        "n_devices": mesh["n_devices"],
        "throughput_ratio_mesh_over_single": round(
            mesh["tokens_per_s"] / max(single["tokens_per_s"], 1e-9), 3),
        "collective_frac": mesh["collective_frac"],
        "prefix_hit_rate_mesh": mesh["prefix_hit_rate"],
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-engine", action="store_true",
                    help="A/B the real engine single-device vs "
                         "mesh-sharded (token parity + throughput) "
                         "instead of the simulator sweep")
    ap.add_argument("--mesh", metavar="DxM", default=None,
                    help="mesh shape for the sharded arm, e.g. 2x2 "
                         "(default: largest the host devices support)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {name, paper_ref, rows, validated} "
                         "to PATH (CI schema)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.real_engine:
        ms = (tuple(int(x) for x in args.mesh.split("x"))
              if args.mesh else None)
        rows = run_real_engine(quick=args.quick, mesh_shape=ms)
        validated = validate_real_engine(rows)
        variant = f"{NAME}_sharded_engine"
    else:
        rows = run(quick=True)
        validated = validate(rows)
        variant = NAME
    for r in rows:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, variant, PAPER_REF, rows,
                                 validated))
