"""Figs. 17/18: scalability over model size and memory capacity.

Llama-7B/13B/30B on A100-80G (Fig. 17: normalized P99 + throughput) and
Llama-7B under 24/48/80 GB memory configs (Fig. 18). Paper claims:
Chameleon wins across all sizes (−60 % P99-ish, 1.4–1.9× throughput);
larger memory ⇒ larger win (more room for adapter caching).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving import NodeConfig, build_node, synthesize
from repro.serving.cost_model import A100_80G, HW_PRESETS, MODEL_PRESETS
from repro.serving.trace import TraceConfig

from .common import run_system, ttft_slo

NAME = "fig17_scalability"
PAPER_REF = "Figures 17 and 18"

# Load levels per model size (bigger model = slower node = lower RPS).
LOADS = {"llama-7b": (8.0, 12.0, 16.0), "llama-13b": (4.0, 6.0, 8.0),
         "llama-30b": (1.5, 2.5, 3.5)}
N_ADAPTERS = {"llama-7b": 500, "llama-13b": 100, "llama-30b": 10}


def _mem_hw(gb: float):
    return dataclasses.replace(A100_80G, hbm_gb=gb, name=f"a100-{gb:.0f}g")


def run(quick: bool = False):
    duration = 45.0 if quick else 120.0
    rows = []
    # --- Fig 17: model sizes on A100-80G ---
    for model in ("llama-7b", "llama-13b", "llama-30b"):
        loads = LOADS[model][:2] if quick else LOADS[model]
        for level, rps in zip(("low", "med", "high"), loads):
            out = {}
            for system in ("slora", "chameleon"):
                m, *_ = run_system(
                    system, rps, duration=duration,
                    node_kw={"hw": "a100-80g", "model": model,
                             "n_adapters": N_ADAPTERS[model]})
                out[system] = m
            rows.append({
                "figure": "17", "model": model, "load": level, "rps": rps,
                "p99_norm": out["chameleon"].p99_ttft()
                    / max(out["slora"].p99_ttft(), 1e-9),
                "goodput_ratio": out["chameleon"].goodput_tokens_per_s()
                    / max(out["slora"].goodput_tokens_per_s(), 1e-9),
            })
    # --- Fig 18: memory capacities, llama-7b ---
    import repro.serving.systems as sysmod
    for gb in (24.0, 48.0, 80.0):
        hw = _mem_hw(gb)
        sysmod.HW_PRESETS[hw.name] = hw
        HW_PRESETS[hw.name] = hw
        out = {}
        for system in ("slora", "chameleon"):
            m, *_ = run_system(system, 10.0, duration=duration,
                               node_kw={"hw": hw.name, "model": "llama-7b",
                                        "n_adapters": 500})
            out[system] = m
        rows.append({
            "figure": "18", "hbm_gb": gb,
            "p99_norm": out["chameleon"].p99_ttft()
                / max(out["slora"].p99_ttft(), 1e-9),
            "hit_gain": out["chameleon"].cache_stats["hit_rate"]
                - out["slora"].cache_stats["hit_rate"],
        })
    return rows


def validate(rows) -> dict:
    f17 = [r for r in rows if r["figure"] == "17"]
    f18 = sorted((r for r in rows if r["figure"] == "18"),
                 key=lambda r: r["hbm_gb"])
    wins = sum(1 for r in f17 if r["p99_norm"] < 1.0)
    return {
        "chameleon_wins_fraction": round(wins / max(len(f17), 1), 2),
        "p99_norm_by_model": {
            m: round(float(np.mean([r["p99_norm"] for r in f17
                                    if r["model"] == m])), 3)
            for m in ("llama-7b", "llama-13b", "llama-30b")},
        "bigger_memory_bigger_win":
            f18[-1]["p99_norm"] <= f18[0]["p99_norm"] + 0.05,
        "p99_norm_by_mem": {r["hbm_gb"]: round(r["p99_norm"], 3)
                            for r in f18},
    }


if __name__ == "__main__":
    rows = run(quick=True)
    for r in rows:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validate(rows))
