"""Shared helpers for the paper-figure benchmarks.

Every benchmark exposes ``run(quick: bool) -> list[dict]`` returning
row dicts, and a module-level ``NAME``/``PAPER_REF``. ``benchmarks.run``
aggregates them into the required ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.serving import (NodeConfig, TraceConfig, build_node, synthesize)
from repro.serving.metrics import slo_from_lowload

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# Calibrated operating points (see EXPERIMENTS.md §Calibration):
# S-LoRA's SLO knee sits at ~9 RPS, Chameleon's at ~12.
LOAD_LOW, LOAD_MED, LOAD_HIGH = 8.0, 10.0, 12.0


def run_system(system: str, rps: float, duration: float = 120.0,
               seed: int = 1, node_kw: dict | None = None,
               trace_kw: dict | None = None):
    cfg = NodeConfig(**(node_kw or {}))
    sim, adapters, cost = build_node(system, cfg)
    trace = synthesize(TraceConfig(rps=rps, duration_s=duration, seed=seed,
                                   **(trace_kw or {})),
                       list(adapters.values()))
    metrics = sim.run(trace)
    return metrics, sim, cost, trace


def ttft_slo(node_kw: dict | None = None) -> float:
    _, adapters, cost = build_node("slora", NodeConfig(**(node_kw or {})))
    trace = synthesize(TraceConfig(rps=1.0, duration_s=30.0, seed=7),
                       list(adapters.values()))
    slo, _ = slo_from_lowload(cost, trace)
    return slo


def save_rows(name: str, rows: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    return path


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def emit_json(path: str, name: str, paper_ref: str, rows: list[dict],
              validated: dict) -> str:
    """Write a benchmark result document in the CI-checked schema.

    Schema (asserted by ``benchmarks.check_json``): top-level keys
    ``name`` / ``paper_ref`` / ``rows`` (non-empty list of flat dicts)
    / ``validated`` (flat dict of derived claims).
    """
    doc = {"name": name, "paper_ref": paper_ref, "rows": rows,
           "validated": validated}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    return path
