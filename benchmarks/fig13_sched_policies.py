"""Fig. 13 (+Fig. 7): scheduling policies under high load.

P99 TTFT over time for FIFO (S-LoRA), SJF (µServe), ChameleonNoCache
and full Chameleon at 12 RPS, plus the per-request slowdown CDF.
Claims: FIFO's tail = short requests blocked behind long (HoL); SJF's
tail = starved long requests (worse P99 than FIFO); the adapter-aware
MLQ removes both.
"""
from __future__ import annotations

import numpy as np

from .common import LOAD_HIGH, run_system

NAME = "fig13_sched_policies"
PAPER_REF = "Figures 7 and 13"

SYSTEMS = ("slora", "userve-sjf", "chameleon-nocache", "chameleon")


def run(quick: bool = False):
    duration = 90.0 if quick else 180.0
    rows = []
    for system in SYSTEMS:
        m, sim, cost, trace = run_system(system, LOAD_HIGH,
                                         duration=duration)
        for t, p99 in m.timeline_p99_ttft(bucket_s=15.0):
            rows.append({"system": system, "t": t, "p99_ttft": p99,
                         "kind": "timeline"})
        sl = np.array([r.slowdown for r in m.records])
        rows.append({"system": system, "kind": "summary",
                     "p99_ttft": m.p99_ttft(), "p50_ttft": m.p50_ttft(),
                     "p50_slowdown": float(np.percentile(sl, 50)),
                     "p99_slowdown": float(np.percentile(sl, 99))})
    return rows


def validate(rows) -> dict:
    s = {r["system"]: r for r in rows if r["kind"] == "summary"}
    return {
        "sjf_tail_worse_than_fifo":
            s["userve-sjf"]["p99_ttft"] > s["slora"]["p99_ttft"],
        "sjf_median_better_than_fifo":
            s["userve-sjf"]["p50_ttft"] < s["slora"]["p50_ttft"],
        "chameleon_sched_beats_both":
            s["chameleon-nocache"]["p99_ttft"]
            < min(s["slora"]["p99_ttft"], s["userve-sjf"]["p99_ttft"]),
        "full_best": s["chameleon"]["p99_ttft"]
            <= s["chameleon-nocache"]["p99_ttft"] * 1.05,
        "p99_ttft": {k: round(v["p99_ttft"], 2) for k, v in s.items()},
    }


if __name__ == "__main__":
    rows = run(quick=True)
    print(validate(rows))
