"""Fig. 14: caching policies at medium load.

Normalized P99 TTFT per adapter rank for S-LoRA (no cache), LRU,
FairShare (equal weights) and Chameleon's cost-aware policy.
Paper: all caches beat no-cache (−18/−22/−26 % overall); cost-aware
helps large ranks most (−12 % vs FairShare at rank 128).
"""
from __future__ import annotations

import numpy as np

from .common import LOAD_MED, run_system

NAME = "fig14_cache_policies"
PAPER_REF = "Figure 14"

SYSTEMS = ("slora", "chameleon-lru", "chameleon-fairshare", "chameleon")


def run(quick: bool = False):
    duration = 60.0 if quick else 180.0
    rows = []
    base = None
    for system in SYSTEMS:
        m, sim, cost, trace = run_system(system, LOAD_MED,
                                         duration=duration)
        per_rank = m.per_rank_p99_ttft()
        overall = m.p99_ttft()
        if system == "slora":
            base = {"overall": overall, **per_rank}
        for rank, v in per_rank.items():
            rows.append({"system": system, "rank": rank, "p99_ttft": v,
                         "normalized": v / base[rank]})
        rows.append({"system": system, "rank": "all", "p99_ttft": overall,
                     "normalized": overall / base["overall"],
                     "hit_rate": m.cache_stats.get("hit_rate", 0.0),
                     "gb_loaded": m.cache_stats.get("gb_loaded", 0.0)})
    return rows


def validate(rows) -> dict:
    overall = {r["system"]: r for r in rows if r["rank"] == "all"}
    red = {s: round(1 - overall[s]["normalized"], 3) for s in SYSTEMS[1:]}
    return {
        "p99_reduction_vs_slora": red,
        "paper": {"chameleon-lru": 0.18, "chameleon-fairshare": 0.22,
                  "chameleon": 0.26},
        "cost_aware_best": overall["chameleon"]["p99_ttft"] <=
            min(overall["chameleon-lru"]["p99_ttft"],
                overall["chameleon-fairshare"]["p99_ttft"]) * 1.02,
    }


if __name__ == "__main__":
    print(validate(run(quick=True)))
