"""Fig. 2/3: single-request TTFT decomposed by adapter rank.

Reproduces the paper's characterization: TTFT of one medium request on
an idle node, broken into base-model execution, (decoupled) adapter
computation, and adapter loading, for ranks 8..128; plus the Fig. 3
input-length sweep (warm adapter). Claims validated:
  - adapter overheads grow with rank;
  - at rank 128, load+compute ≈ 60 % of TTFT and load alone ≈ 17.5 %.
"""
from __future__ import annotations

from repro.serving.cost_model import A40, LLAMA_7B, CostModel

NAME = "fig02_rank_heterogeneity"
PAPER_REF = "Figures 2 and 3"

RANKS = (8, 16, 32, 64, 128)


def run(quick: bool = False):
    cost = CostModel(hw=A40, model=LLAMA_7B)
    rows = []
    inp = 256                      # "medium input" [50]
    for rank in RANKS:
        base = cost.prefill_time([inp], [0])   # rank-0 = base model only
        full = cost.prefill_time([inp], [rank])
        adapter_compute = full - base
        load = cost.adapter_load_time(rank)
        ttft = load + full
        rows.append({
            "figure": "2", "rank": rank, "input_len": inp,
            "base_ms": base * 1e3,
            "adapter_compute_ms": adapter_compute * 1e3,
            "adapter_load_ms": load * 1e3,
            "ttft_ms": ttft * 1e3,
            "load_frac": load / ttft,
            "overhead_frac": (ttft - base) / ttft,
        })
    for inp in (128, 256, 512, 1024) if not quick else (256,):
        for rank in RANKS:
            t = cost.prefill_time([inp], [rank])
            rows.append({"figure": "3", "rank": rank, "input_len": inp,
                         "ttft_warm_ms": t * 1e3})
    return rows


def validate(rows) -> dict:
    r128 = next(r for r in rows if r["figure"] == "2" and r["rank"] == 128)
    return {
        "rank128_load_frac": round(r128["load_frac"], 3),
        "rank128_overhead_frac": round(r128["overhead_frac"], 3),
        "paper_load_frac": 0.175, "paper_overhead_frac": 0.60,
    }


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
