"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = harness wall
time; derived = the figure's headline validation numbers) and writes
per-figure row dumps under results/.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from . import (cluster_routing, fig02_rank_heterogeneity,
               fig06_heavytail_cdf, fig10_latency_load,
               fig13_sched_policies, fig14_cache_policies,
               fig15_prefetch, fig16_sensitivity, fig17_scalability,
               roofline_table)
from .common import save_rows

MODULES = (fig02_rank_heterogeneity, fig06_heavytail_cdf,
           fig10_latency_load, fig13_sched_policies,
           fig14_cache_policies, fig15_prefetch, fig16_sensitivity,
           fig17_scalability, cluster_routing, roofline_table)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-length traces (slower, EXPERIMENTS.md "
                         "numbers); default is quick mode")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for mod in MODULES:
        if args.only and args.only not in mod.NAME:
            continue
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
            derived = mod.validate(rows) if hasattr(mod, "validate") else {}
            save_rows(mod.NAME, rows)
        except Exception as e:                      # noqa: BLE001
            derived = {"error": f"{type(e).__name__}: {e}"}
            rows = []
        us = (time.time() - t0) * 1e6
        print(f"{mod.NAME},{us:.0f},"
              f"\"{json.dumps(derived, default=str)}\"")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
