"""§Roofline: the full baseline table from the dry-run artifacts."""
from __future__ import annotations

import json
import os

from repro.roofline.analysis import analyze_file, whats_the_bottleneck

NAME = "roofline_table"
PAPER_REF = "EXPERIMENTS.md §Roofline"

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun.json")


def run(quick: bool = False):
    if not os.path.exists(DRYRUN):
        return [{"error": "results/dryrun.json missing — run "
                          "`python -m repro.launch.dryrun` first"}]
    rows = []
    for mesh in ("16x16", "2x16x16"):
        for r in analyze_file(DRYRUN, mesh=mesh):
            d = r.table_row()
            d["next_move"] = whats_the_bottleneck(r)
            rows.append(d)
    return rows


def validate(rows) -> dict:
    single = [r for r in rows if r.get("mesh") == "16x16"]
    doms = {}
    for r in single:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return {"cells_analyzed_single_pod": len(single),
            "dominant_term_histogram": doms}


def print_table(rows) -> None:
    hdr = (f"{'arch':<26} {'shape':<12} {'mesh':<8} {'compute_s':>10} "
           f"{'memory_s':>10} {'collect_s':>10} {'dominant':>10} "
           f"{'useful':>7} {'mfu_bnd':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(r["error"])
            continue
        print(f"{r['arch']:<26} {r['shape']:<12} {r['mesh']:<8} "
              f"{r['compute_s']:>10.4f} {r['memory_s']:>10.4f} "
              f"{r['collective_s']:>10.4f} {r['dominant']:>10} "
              f"{r['useful_ratio']:>7.3f} {r['mfu_bound']:>8.4f}")


if __name__ == "__main__":
    rows = run()
    print_table(rows)
    print(validate(rows))
