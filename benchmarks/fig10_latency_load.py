"""Figs. 10/11/12: P99 TTFT, P99 TBT, P50 TTFT vs load; throughput.

Sweeps RPS for S-LoRA, ChameleonNoCache, ChameleonNoSched and full
Chameleon; derives each system's SLO knee (throughput) and the paper's
headline claims at high load:
  paper: −80.7 % P99 TTFT, −48.1 % P50 TTFT, 1.5× throughput.
"""
from __future__ import annotations

import numpy as np

from .common import LOAD_HIGH, run_system, ttft_slo

NAME = "fig10_latency_load"
PAPER_REF = "Figures 10, 11, 12"

SYSTEMS = ("slora", "chameleon-nocache", "chameleon-nosched", "chameleon")


def run_paged_ab(n_requests: int = 32, seed: int = 0,
                 quick: bool = False) -> list[dict]:
    """A/B the *real* engine with dense vs paged KV at identical load.

    Same model, same requests, same control plane — the only variable
    is the KV data plane. Dense reserves input + predicted output per
    request up front, so the adapter cache is squeezed by a prediction;
    paged holds only allocated pages, so the cache keeps more adapters
    resident (higher hit rate) and admission sees real headroom.
    ``MemoryPool.check_invariants()`` runs after every engine step.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import Request
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig

    cfg = get_config("chameleon-llama-7b").reduced()
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    if quick:
        n_requests = min(n_requests, 16)
    # Long decodes are where the dense worst-case reservation hurts:
    # dense holds input + predicted output from admission, squeezing
    # the adapter cache for the request's whole lifetime.
    rng = np.random.default_rng(seed)
    specs = [(int(rng.integers(16, 64)), int(rng.integers(64, 160)),
              int(rng.integers(0, 16))) for _ in range(n_requests)]

    rows = []
    tokens_by_mode = {}
    for paged in (False, True):
        # One variable per A/B: the KV layout. Async loading and the
        # prefetchers (their own A/B lives in run_loading_ab) are
        # pinned off so both runs schedule deterministically.
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=256, n_lora_slots=16, n_adapters=16,
            seed=seed, paged=paged, async_load=False,
            queued_prefetch=False, histogram_prefetch=False))
        # Unified surface: handles stream the tokens; the A/B asserts
        # the streamed tokens equal the engine's internal record and
        # (below) are identical across KV layouts — greedy sampling is
        # the pre-SamplingParams argmax, bit for bit.
        handles = [eng.submit(Request(input_len=i, output_len=o,
                                      adapter_id=a))
                   for i, o, a in specs]
        steps = 0
        while eng.busy() and steps < 50_000:
            eng.step()
            eng.pool.check_invariants()
            steps += 1
        streamed = [h.tokens for h in handles]
        assert streamed == [eng.outputs[h.req_id] for h in handles], \
            "handle streams diverged from the engine output record"
        tokens_by_mode["paged" if paged else "dense"] = streamed
        m = eng.metrics()
        # Uniform row keys across modes (the CI schema check requires
        # it): dense reports zeroed page stats.
        page_stats = {"kv_pages_used": 0, "kv_pages_total": 0,
                      "kv_page_util": 0.0, "preempted": eng.n_preempted}
        page_stats.update(eng.kv_page_stats())
        rows.append({
            "mode": "paged" if paged else "dense",
            "submitted": n_requests,
            "completed": len(eng.completed),
            "hit_rate": m.cache_stats["hit_rate"],
            "adapter_gb_loaded": m.cache_stats["gb_loaded"],
            "evictions": m.cache_stats["evictions"],
            "batch_occupancy_mean":
                m.sched_stats["batch_occupancy_mean"],
            "steps": steps,
            "tokens_identical_to_dense":
                tokens_by_mode.get("dense") == streamed,
            **page_stats,
        })
    return rows


def validate_paged(rows) -> dict:
    dense = next(r for r in rows if r["mode"] == "dense")
    paged = next(r for r in rows if r["mode"] == "paged")
    return {
        # Both runs must fully drain — equal truncation is not success.
        "all_completed":
            dense["completed"] == dense["submitted"]
            and paged["completed"] == paged["submitted"],
        # Greedy SamplingParams must reproduce the pre-redesign tokens
        # exactly: paged and dense decode the identical stream.
        "tokens_identical": bool(paged["tokens_identical_to_dense"]),
        "hit_rate_dense": round(dense["hit_rate"], 4),
        "hit_rate_paged": round(paged["hit_rate"], 4),
        "occupancy_dense": dense["batch_occupancy_mean"],
        "occupancy_paged": paged["batch_occupancy_mean"],
        # The acceptance claim: paged strictly beats dense on at least
        # one of cache hit rate / admitted-batch occupancy.
        "paged_beats_dense":
            paged["hit_rate"] > dense["hit_rate"]
            or paged["batch_occupancy_mean"]
            > dense["batch_occupancy_mean"],
    }


def run_loading_ab(n_requests: int = 36, seed: int = 0,
                   quick: bool = False) -> list[dict]:
    """A/B the *real* engine: synchronous vs overlapped adapter loading.

    Same model, same requests, same modeled H2D bandwidth — the only
    variable is ``EngineConfig.async_load``. Sync mode blocks the whole
    step loop for every adapter transfer (S-LoRA batch-launch
    semantics, simulator's ``sync_adapter_load``); async mode
    dispatches the slot write, keeps decoding, and defers only the
    loading request (paper §4 "minimize adapter loading times"). Many
    adapters churning through few slots put loads on the critical path,
    so overlapping them must show up in tail TTFT.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import Request
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig

    cfg = get_config("chameleon-llama-7b").reduced()
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    if quick:
        n_requests = min(n_requests, 24)
    rng = np.random.default_rng(seed)
    # Fixed input length -> one prefill bucket, so jit compiles once in
    # warmup and the measured phase times loads, not compiles.
    specs = [(24, int(rng.integers(8, 24)), int(rng.integers(0, 16)))
             for _ in range(n_requests)]

    rows = []
    for async_load in (False, True):
        ecfg = EngineConfig(max_slots=4, max_len=128, n_lora_slots=4,
                            n_adapters=16, seed=seed,
                            async_load=async_load, h2d_gbps=0.0)
        eng = ChameleonEngine(cfg, params, ecfg)
        # Warmup: compile prefill/decode and then drop residency state
        # back to a cold-ish cache by the measured phase's adapters.
        warm = Request(input_len=24, output_len=4, adapter_id=15)
        warm.arrival_time = eng.now()
        eng.submit(warm)
        eng.run_until_drained()
        eng.reset_stats()
        # Model the H2D link only for the measured phase: ~12 ms per
        # adapter at the catalog's mean size.
        mean_bytes = float(np.mean(
            [i.size_bytes for i in eng.catalog.infos.values()]))
        eng.ecfg.h2d_gbps = mean_bytes / 0.012 / 1e9
        reqs = []
        for i, o, a in specs:
            r = Request(input_len=i, output_len=o, adapter_id=a)
            r.arrival_time = eng.now()
            reqs.append(r)
            eng.submit(r)
        steps = 0
        while eng.busy() and steps < 200_000:
            eng.step()
            steps += 1
        m = eng.metrics()
        rows.append({
            "mode": "overlapped" if async_load else "sync",
            "submitted": n_requests,
            "completed": len(eng.completed),
            "p50_ttft": m.p50_ttft(),
            "p99_ttft": m.p99_ttft(),
            "p99_tbt": m.p99_tbt(),
            "adapter_loads": m.cache_stats["misses"],
            "gb_loaded": m.cache_stats["gb_loaded"],
            "deferred": m.sched_stats["deferred"],
            "async_loads": m.sched_stats["async_loads"],
            "steps": steps,
        })
    return rows


def validate_loading(rows) -> dict:
    sync = next(r for r in rows if r["mode"] == "sync")
    over = next(r for r in rows if r["mode"] == "overlapped")
    return {
        "all_completed":
            sync["completed"] == sync["submitted"]
            and over["completed"] == over["submitted"],
        "p99_ttft_sync": round(sync["p99_ttft"], 4),
        "p99_ttft_overlapped": round(over["p99_ttft"], 4),
        "p99_ttft_reduction": round(
            1 - over["p99_ttft"] / max(sync["p99_ttft"], 1e-9), 3),
        # The acceptance claim: overlapped loading improves P99 TTFT at
        # identical load and identical modeled H2D bandwidth.
        "overlap_beats_sync_p99_ttft":
            over["p99_ttft"] < sync["p99_ttft"],
        "overlap_deferred_placements": over["deferred"],
    }


def run_hotloop_ab(n_requests: int = 32, seed: int = 0,
                   quick: bool = False) -> list[dict]:
    """A/B the *real* engine: seed two-dispatch loop vs the fused
    device-resident hot loop, at identical load through the existing
    paper-figure pipeline (end-to-end TTFT/TBT impact, not just the
    ``decode_hotloop.py`` microbenchmark). Same model, same requests,
    same control plane — the only variable is
    ``EngineConfig.fused_hotloop``. Under queue backlog the fused loop
    runs K=1 (admission latency untouched), so the win here is the
    fused dispatch + device-resident state, with horizons opening as
    the queue drains."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import Request
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig

    cfg = get_config("chameleon-llama-7b").reduced()
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    if quick:
        n_requests = min(n_requests, 16)
    rng = np.random.default_rng(seed)
    specs = [(int(rng.integers(16, 48)), int(rng.integers(32, 128)),
              int(rng.integers(0, 16))) for _ in range(n_requests)]

    rows = []
    tokens_by_mode = {}
    for fused in (False, True):
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=256, n_lora_slots=16, n_adapters=16,
            seed=seed, fused_hotloop=fused, async_load=False,
            queued_prefetch=False, histogram_prefetch=False))
        # Warmup one short request (jit compiles), then measure.
        eng.submit(Request(input_len=16, output_len=4, adapter_id=15))
        eng.run_until_drained()
        eng.reset_stats()
        reqs = []
        for i, o, a in specs:
            r = Request(input_len=i, output_len=o, adapter_id=a)
            r.arrival_time = eng.now()
            reqs.append(r)
        handles = [eng.submit(r) for r in reqs]
        steps = 0
        while eng.busy() and steps < 200_000:
            eng.step()
            steps += 1
        m = eng.metrics()
        mode = "fused" if fused else "seed"
        tokens_by_mode[mode] = [h.tokens for h in handles]
        rows.append({
            "mode": mode,
            "submitted": n_requests,
            "completed": len(eng.completed),
            "p50_ttft": m.p50_ttft(),
            "p99_ttft": m.p99_ttft(),
            "p99_tbt": m.p99_tbt(),
            "steps": steps,
            "batch_epoch": eng.stats()["batch_epoch"],
            "tokens_identical_to_seed":
                tokens_by_mode.get("seed") == tokens_by_mode[mode],
        })
    return rows


def validate_hotloop(rows) -> dict:
    seed = next(r for r in rows if r["mode"] == "seed")
    fused = next(r for r in rows if r["mode"] == "fused")
    return {
        "all_completed":
            seed["completed"] == seed["submitted"]
            and fused["completed"] == fused["submitted"],
        # The microbenchmark's bar, held end-to-end: identical tokens.
        "tokens_identical": bool(fused["tokens_identical_to_seed"]),
        "p99_ttft_seed": round(seed["p99_ttft"], 4),
        "p99_ttft_fused": round(fused["p99_ttft"], 4),
        "p99_tbt_seed": round(seed["p99_tbt"], 4),
        "p99_tbt_fused": round(fused["p99_tbt"], 4),
        "e2e_steps_seed": seed["steps"],
        "e2e_steps_fused": fused["steps"],
        # Directional (not asserted in CI — wall-clock percentiles on
        # a shared runner): the fused loop must not regress TTFT tails
        # (K=1 under backlog keeps admission latency untouched). P99
        # TBT is *expected* to rise at idle-queue horizons — K tokens
        # arrive per sync, the documented burst-delivery trade-off
        # (DESIGN §6) — so it is reported above, not flagged.
        "fused_not_worse_p99_ttft":
            fused["p99_ttft"] <= seed["p99_ttft"] * 1.05,
    }


def run_spec_ab(n_requests: int = 32, seed: int = 0,
                quick: bool = False) -> list[dict]:
    """A/B the *real* engine: fused loop with vs without speculative
    draft-verify decoding, at identical end-to-end load (queue
    backlog, adapter churn, admission — not just the
    ``decode_hotloop.py --spec`` hot-loop isolation). Same model, same
    requests, same control plane — the only variable is
    ``EngineConfig.spec_decode``. The draft is the target's own first
    layer (remaining layers' residual projections zeroed, LoRA deltas
    zeroed), so acceptance is 1.0 by construction and the A/B measures
    the mechanism: under backlog speculation demotes itself to K=1
    (TTFT untouched), opening drafted bursts as the queue drains.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import Request
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig

    from benchmarks.decode_hotloop import (_shared_layer_draft,
                                           _zeroed_catalog)

    cfg = get_config("chameleon-llama-7b").reduced()
    base = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                 jnp.float32)
    params, dcfg, dparams = _shared_layer_draft(cfg, base)
    if quick:
        n_requests = min(n_requests, 16)
    rng = np.random.default_rng(seed)
    specs = [(int(rng.integers(16, 48)), int(rng.integers(32, 128)),
              int(rng.integers(0, 16))) for _ in range(n_requests)]

    rows = []
    tokens_by_mode = {}
    for spec in (False, True):
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=256, n_lora_slots=16, n_adapters=16,
            seed=seed, fused_hotloop=True, spec_decode=spec,
            async_load=False, queued_prefetch=False,
            histogram_prefetch=False),
            catalog=_zeroed_catalog(cfg, n_adapters=16),
            draft=(dcfg, dparams) if spec else None)
        # Warmup: a full batch of medium decodes so the fused-horizon
        # *and* speculative jit shapes (draft catch-up buckets, spec
        # rounds) compile before the measured phase.
        for i in range(4):
            eng.submit(Request(input_len=16, output_len=3 * 8,
                               adapter_id=12 + i))
        eng.run_until_drained()
        eng.reset_stats()
        reqs = []
        for i, o, a in specs:
            r = Request(input_len=i, output_len=o, adapter_id=a)
            r.arrival_time = eng.now()
            reqs.append(r)
        handles = [eng.submit(r) for r in reqs]
        steps = 0
        while eng.busy() and steps < 200_000:
            eng.step()
            eng.pool.check_invariants()
            steps += 1
        m = eng.metrics()
        mode = "spec" if spec else "nonspec"
        tokens_by_mode[mode] = [h.tokens for h in handles]
        # Uniform row keys across arms (CI schema): the nonspec arm
        # reports zeroed speculation gauges.
        sstats = {"spec_accept_rate": 0.0, "spec_drafted_tokens": 0,
                  "spec_accepted_tokens": 0, "spec_draft_dispatches": 0,
                  "spec_verify_dispatches": 0, "spec_dispatches": 0,
                  "spec_k_eff": 0}
        sstats.update(eng.spec_stats())
        rows.append({
            "mode": mode,
            "submitted": n_requests,
            "completed": len(eng.completed),
            "p50_ttft": m.p50_ttft(),
            "p99_ttft": m.p99_ttft(),
            "p99_tbt": m.p99_tbt(),
            "steps": steps,
            "tokens_identical_to_nonspec":
                tokens_by_mode.get("nonspec") == tokens_by_mode[mode],
            **sstats,
        })
    return rows


def validate_spec(rows) -> dict:
    non = next(r for r in rows if r["mode"] == "nonspec")
    sp = next(r for r in rows if r["mode"] == "spec")
    return {
        "all_completed":
            non["completed"] == non["submitted"]
            and sp["completed"] == sp["submitted"],
        # The tentpole bar, held end-to-end through the scheduler:
        # greedy speculation changes dispatch counts, never tokens.
        "tokens_identical": bool(sp["tokens_identical_to_nonspec"]),
        "spec_accept_rate": sp["spec_accept_rate"],
        "spec_drafted_tokens": sp["spec_drafted_tokens"],
        "spec_verify_dispatches": sp["spec_verify_dispatches"],
        "p99_ttft_nonspec": round(non["p99_ttft"], 4),
        "p99_ttft_spec": round(sp["p99_ttft"], 4),
        "p99_tbt_nonspec": round(non["p99_tbt"], 4),
        "p99_tbt_spec": round(sp["p99_tbt"], 4),
        "e2e_steps_nonspec": non["steps"],
        "e2e_steps_spec": sp["steps"],
        # Directional (wall-clock on a shared runner, like the hotloop
        # A/B): K=1 demotion under backlog must keep TTFT tails flat.
        "spec_not_worse_p99_ttft":
            sp["p99_ttft"] <= non["p99_ttft"] * 1.05,
    }


def run_prefix_ab(n_requests: int = 32, seed: int = 0,
                  quick: bool = False) -> list[dict]:
    """A/B the *real* engine: prefix KV reuse off vs on, at identical
    load on a shared-prefix-heavy trace (``synthesize_shared_prefix``).

    Four arms isolate one variable each: ``off``/``on`` under the
    default exact mode (same-adapter reuse, token-identical by
    construction) and ``off_cross``/``on_cross`` under aLoRA mode
    (base-model prompt prefill → cross-adapter reuse; both arms of the
    pair prefill identically, so the A/B stays paired). Prompts are 4
    preambles of 48 tokens (3 KV pages) + fixed-length unique suffixes,
    so the prefill bucket set stays small and warmup can compile every
    (miss, hit) shape before the measured phase.
    ``MemoryPool.check_invariants()`` runs after every engine step.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import AdapterInfo, Request
    from repro.models import api as model_api
    from repro.serving.engine import ChameleonEngine, EngineConfig
    from repro.serving.trace import TraceConfig, synthesize_shared_prefix

    cfg = get_config("chameleon-llama-7b").reduced()
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    if quick:
        n_requests = min(n_requests, 16)
    apool = [AdapterInfo(adapter_id=i, rank=8, size_bytes=2000,
                         size_tokens=20) for i in range(16)]
    tcfg = TraceConfig(rps=8.0, duration_s=max(n_requests, 8),
                       n_adapters=16, seed=seed)
    trace = synthesize_shared_prefix(tcfg, apool, n_prefixes=4,
                                     prefix_len=48, suffix_min=8,
                                     suffix_max=8, vocab_size=4096)
    specs = [(list(r.prompt), max(2, min(r.output_len, 24)),
              r.adapter_id) for r in trace.requests[:n_requests]]
    assert len(specs) == n_requests, "trace too short for n_requests"

    arms = [("off", False, "exact"), ("on", True, "exact"),
            ("off_cross", False, "alora"), ("on_cross", True, "alora")]
    ref_of = {"on": "off", "on_cross": "off_cross"}
    rows = []
    tokens_by_mode = {}
    for mode, use_prefix, pmode in arms:
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=128, n_lora_slots=16, n_adapters=16,
            seed=seed, async_load=False, queued_prefetch=False,
            histogram_prefetch=False, prefix_cache=use_prefix,
            prefix_mode=pmode))
        # Warmup: replay the workload twice — round 1 compiles the
        # miss-path buckets and populates the tree, round 2 compiles
        # the hit-path suffix buckets — then reset counters. The tree
        # stays warm (resident prefixes, like resident adapters), so
        # the measured phase is the steady state.
        for _ in range(2):
            for p, _, a in specs:
                eng.submit(Request(input_len=len(p), output_len=2,
                                   adapter_id=a, prompt=list(p)))
            eng.run_until_drained()
        eng.reset_stats()
        handles = []
        for p, o, a in specs:
            r = Request(input_len=len(p), output_len=o, adapter_id=a,
                        prompt=list(p))
            r.arrival_time = eng.now()
            handles.append(eng.submit(r))
        steps = 0
        while eng.busy() and steps < 200_000:
            eng.step()
            eng.pool.check_invariants()
            steps += 1
        m = eng.metrics()
        tokens_by_mode[mode] = [h.tokens for h in handles]
        # Uniform row keys across arms: off arms report zeroed
        # prefix stats (the CI schema check requires consistency).
        pstats = {"prefix_hit_rate": 0.0, "prefix_hit_tokens": 0,
                  "prefix_lookup_tokens": 0, "prefix_hits": 0,
                  "prefix_shared_pages": 0, "prefix_nodes": 0,
                  "prefix_evictions": 0, "cow_forks": 0}
        pstats.update(eng.prefix_stats())
        rows.append({
            "mode": mode,
            "submitted": n_requests,
            "completed": len(eng.completed),
            "p50_ttft": m.p50_ttft(),
            "p99_ttft": m.p99_ttft(),
            "p99_tbt": m.p99_tbt(),
            "steps": steps,
            "tokens_identical_to_off":
                tokens_by_mode[ref_of.get(mode, mode)]
                == tokens_by_mode[mode],
            **pstats,
        })
    return rows


def validate_prefix(rows) -> dict:
    r = {row["mode"]: row for row in rows}
    return {
        # Every arm must fully drain — equal truncation is not success.
        "all_completed": all(x["completed"] == x["submitted"]
                             for x in rows),
        # The tentpole bar: reuse changes where prompt KV comes from,
        # never which tokens come out — per mode pair.
        "tokens_identical": bool(r["on"]["tokens_identical_to_off"]),
        "tokens_identical_cross":
            bool(r["on_cross"]["tokens_identical_to_off"]),
        "prefix_hit_rate": r["on"]["prefix_hit_rate"],
        "prefix_hit_rate_cross": r["on_cross"]["prefix_hit_rate"],
        "p99_ttft_off": round(r["off"]["p99_ttft"], 4),
        "p99_ttft_on": round(r["on"]["p99_ttft"], 4),
        "p99_ttft_reduction": round(
            1 - r["on"]["p99_ttft"] / max(r["off"]["p99_ttft"], 1e-9),
            3),
        # The acceptance claim: skipping cached-prefix prefill shows up
        # in tail TTFT at identical load (wall-clock — the CI job
        # allows one retry, like the loading A/B).
        "prefix_reduces_p99_ttft":
            r["on"]["p99_ttft"] < r["off"]["p99_ttft"],
    }


def run(quick: bool = False):
    rps_grid = (8.0, 10.0, 11.0, 12.0, 13.0) if quick else \
        (6.0, 8.0, 9.0, 10.0, 10.5, 11.0, 11.5, 12.0, 13.0, 14.0)
    duration = 120.0 if quick else 180.0
    slo = ttft_slo()
    rows = []
    for system in SYSTEMS:
        for rps in rps_grid:
            m, sim, cost, trace = run_system(system, rps,
                                             duration=duration)
            rows.append({
                "system": system, "rps": rps,
                "p99_ttft": m.p99_ttft(), "p50_ttft": m.p50_ttft(),
                "p99_tbt": m.p99_tbt(),
                "slo": slo, "violates": m.p99_ttft() > slo,
                "hit_rate": m.cache_stats.get("hit_rate", 0.0),
            })
    return rows


def knee(rows, system) -> float:
    """Highest load sustained without P99-TTFT SLO violation."""
    ok = [r["rps"] for r in rows if r["system"] == system
          and not r["violates"]]
    return max(ok) if ok else 0.0


def validate(rows) -> dict:
    k_s, k_c = knee(rows, "slora"), knee(rows, "chameleon")
    hi = max(r["rps"] for r in rows)
    at = lambda sys_, f: next(r[f] for r in rows
                              if r["system"] == sys_ and r["rps"] == hi)
    p99_red = 1 - at("chameleon", "p99_ttft") / at("slora", "p99_ttft")
    p50_red = 1 - at("chameleon", "p50_ttft") / at("slora", "p50_ttft")
    return {
        "slora_knee_rps": k_s, "chameleon_knee_rps": k_c,
        "throughput_ratio": round(k_c / max(k_s, 1e-9), 2),
        "p99_ttft_reduction_at_high": round(p99_red, 3),
        "p50_ttft_reduction_at_high": round(p50_red, 3),
        "paper": {"throughput_ratio": 1.5, "p99_reduction": 0.807,
                  "p50_reduction": 0.481},
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="A/B the real engine dense vs paged KV "
                         "instead of the simulator load sweep")
    ap.add_argument("--loading", action="store_true",
                    help="A/B the real engine sync vs overlapped "
                         "adapter loading")
    ap.add_argument("--hotloop", action="store_true",
                    help="A/B the real engine seed vs fused decode "
                         "hot loop at identical load")
    ap.add_argument("--spec", action="store_true",
                    help="A/B the real engine fused loop with vs "
                         "without speculative draft-verify decoding "
                         "at identical load")
    ap.add_argument("--prefix", action="store_true",
                    help="A/B the real engine prefix KV reuse off vs "
                         "on (exact + cross-adapter aLoRA modes) on a "
                         "shared-prefix-heavy trace")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {name, paper_ref, rows, validated} "
                         "to PATH (CI schema)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.paged:
        rows = run_paged_ab(quick=args.quick)
        validated = validate_paged(rows)
        variant = f"{NAME}_paged_ab"
    elif args.loading:
        rows = run_loading_ab(quick=args.quick)
        validated = validate_loading(rows)
        variant = f"{NAME}_loading_ab"
    elif args.hotloop:
        rows = run_hotloop_ab(quick=args.quick)
        validated = validate_hotloop(rows)
        variant = f"{NAME}_hotloop_ab"
    elif args.spec:
        rows = run_spec_ab(quick=args.quick)
        validated = validate_spec(rows)
        variant = f"{NAME}_spec_ab"
    elif args.prefix:
        rows = run_prefix_ab(quick=args.quick)
        validated = validate_prefix(rows)
        variant = f"{NAME}_prefix_ab"
    else:
        rows = run(quick=True)
        validated = validate(rows)
        variant = NAME
    for r in rows:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, variant, PAPER_REF, rows,
                                 validated))
