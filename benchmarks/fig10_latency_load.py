"""Figs. 10/11/12: P99 TTFT, P99 TBT, P50 TTFT vs load; throughput.

Sweeps RPS for S-LoRA, ChameleonNoCache, ChameleonNoSched and full
Chameleon; derives each system's SLO knee (throughput) and the paper's
headline claims at high load:
  paper: −80.7 % P99 TTFT, −48.1 % P50 TTFT, 1.5× throughput.
"""
from __future__ import annotations

import numpy as np

from .common import LOAD_HIGH, run_system, ttft_slo

NAME = "fig10_latency_load"
PAPER_REF = "Figures 10, 11, 12"

SYSTEMS = ("slora", "chameleon-nocache", "chameleon-nosched", "chameleon")


def run(quick: bool = False):
    rps_grid = (8.0, 10.0, 11.0, 12.0, 13.0) if quick else \
        (6.0, 8.0, 9.0, 10.0, 10.5, 11.0, 11.5, 12.0, 13.0, 14.0)
    duration = 120.0 if quick else 180.0
    slo = ttft_slo()
    rows = []
    for system in SYSTEMS:
        for rps in rps_grid:
            m, sim, cost, trace = run_system(system, rps,
                                             duration=duration)
            rows.append({
                "system": system, "rps": rps,
                "p99_ttft": m.p99_ttft(), "p50_ttft": m.p50_ttft(),
                "p99_tbt": m.p99_tbt(),
                "slo": slo, "violates": m.p99_ttft() > slo,
                "hit_rate": m.cache_stats.get("hit_rate", 0.0),
            })
    return rows


def knee(rows, system) -> float:
    """Highest load sustained without P99-TTFT SLO violation."""
    ok = [r["rps"] for r in rows if r["system"] == system
          and not r["violates"]]
    return max(ok) if ok else 0.0


def validate(rows) -> dict:
    k_s, k_c = knee(rows, "slora"), knee(rows, "chameleon")
    hi = max(r["rps"] for r in rows)
    at = lambda sys_, f: next(r[f] for r in rows
                              if r["system"] == sys_ and r["rps"] == hi)
    p99_red = 1 - at("chameleon", "p99_ttft") / at("slora", "p99_ttft")
    p50_red = 1 - at("chameleon", "p50_ttft") / at("slora", "p50_ttft")
    return {
        "slora_knee_rps": k_s, "chameleon_knee_rps": k_c,
        "throughput_ratio": round(k_c / max(k_s, 1e-9), 2),
        "p99_ttft_reduction_at_high": round(p99_red, 3),
        "p50_ttft_reduction_at_high": round(p50_red, 3),
        "paper": {"throughput_ratio": 1.5, "p99_reduction": 0.807,
                  "p50_reduction": 0.481},
    }


if __name__ == "__main__":
    rows = run(quick=True)
    for r in rows:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validate(rows))
