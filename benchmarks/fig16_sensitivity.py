"""Fig. 16: output-length-predictor accuracy sensitivity.

Chameleon (full WRS) vs OutputOnly (µServe-style size = predicted
output alone) at accuracies 100/80/60 %, under a bursty trace (the
paper's spike at ~300 s). Claims: WRS's multi-factor size makes the
scheduler robust at 80 %; OutputOnly degrades much faster at 60 %.
"""
from __future__ import annotations

from .common import LOAD_MED, run_system

NAME = "fig16_sensitivity"
PAPER_REF = "Figure 16"


def run(quick: bool = False):
    duration = 60.0 if quick else 180.0
    rows = []
    for system in ("chameleon", "chameleon-outputonly"):
        for acc in (1.0, 0.8, 0.6):
            m, sim, cost, trace = run_system(
                system, LOAD_MED + 1.0, duration=duration,
                node_kw={"predictor_accuracy": acc},
                trace_kw={"burstiness": 1.0})
            rows.append({"system": system, "accuracy": acc,
                         "p99_ttft": m.p99_ttft(),
                         "p50_ttft": m.p50_ttft(),
                         "squashed": m.sched_stats.get("squashed", 0)})
    return rows


def validate(rows) -> dict:
    get = lambda s, a: next(r["p99_ttft"] for r in rows
                            if r["system"] == s and r["accuracy"] == a)
    cham_delta = get("chameleon", 0.6) / max(get("chameleon", 1.0), 1e-9)
    oo_delta = (get("chameleon-outputonly", 0.6)
                / max(get("chameleon-outputonly", 1.0), 1e-9))
    return {
        "chameleon_p99_degradation_60pct": round(cham_delta, 2),
        "outputonly_p99_degradation_60pct": round(oo_delta, 2),
        "wrs_more_robust": cham_delta <= oo_delta * 1.05,
        "negligible_loss_at_80pct": get("chameleon", 0.8)
            <= get("chameleon", 1.0) * 1.5,
    }


if __name__ == "__main__":
    rows = run(quick=True)
    for r in rows:
        print(r)
    print(validate(rows))
