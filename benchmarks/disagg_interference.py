"""Disaggregation interference A/B: monolithic cluster vs
prefill/decode-split cluster (DESIGN §3.4, ROADMAP 3).

The claim under test: with every replica running prefill and decode
interleaved, a burst of long prompts stalls the in-flight decode
stream on whichever replicas take them — the decode stream's tail TBT
spikes for the duration of each monolithic prefill. Splitting the
fleet into prefill and decode roles (``DisaggCluster``) moves those
prefills off the decode replicas entirely; the stream's tail TBT
during the bursts should drop at identical load, and every request's
tokens must be bit-for-bit identical to the monolithic cluster's
(copied KV + page-table indirection + deterministic sampling).

Workload: a steady decode stream (short prompts, long outputs)
arriving first, then bursts of long-prompt/short-output requests
landing mid-decode. Both systems replay the *same* requests at the
same arrival times over the same total replica count; the only
variable is the cluster topology.

Reported per system: stream P50/P99 TBT, burst P99 TTFT, completion,
goodput, and (disagg) handoff count/bytes/wait plus per-role
utilization. Emits the CI-checked BENCH JSON schema via ``--json``
(``benchmarks/check_json.py`` requires ``all_completed`` and
``tokens_identical``); ``--quick`` shrinks the workload for the
disagg-smoke job.
"""
from __future__ import annotations

import time

import numpy as np

NAME = "disagg_interference"
PAPER_REF = ("Chameleon §6 (cluster composition); DistServe/InfiniLoRA "
             "prefill-decode disaggregation (PAPERS.md)")


def _model(seed=0):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import api as model_api

    # Dispatch-bound reduced model (decode_hotloop's trick): the A/B
    # isolates *scheduling* interference, not per-token FLOPs, and the
    # token-identity assertion pins correctness at any size.
    cfg = get_config("chameleon-llama-7b").reduced(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=128)
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    return cfg, params


def _workload(quick: bool, seed: int):
    """(requests, is_stream flags). Stream requests arrive first and
    decode for the whole run; long-prompt bursts land mid-decode."""
    from repro.core import Request

    rng = np.random.default_rng(seed)
    n_stream = 4 if quick else 8
    stream_out = 96 if quick else 192
    n_bursts = 2 if quick else 3
    burst_size = 2 if quick else 4
    burst_in = 192 if quick else 224

    reqs, flags = [], []
    for i in range(n_stream):
        reqs.append(Request(
            input_len=12, output_len=stream_out, adapter_id=i % 4,
            arrival_time=0.01 * i,
            prompt=[int(x) for x in rng.integers(1, 120, 12)]))
        flags.append(True)
    for b in range(n_bursts):
        t = 0.15 + 0.2 * b
        for j in range(burst_size):
            reqs.append(Request(
                input_len=burst_in, output_len=4,
                adapter_id=4 + (b + j) % 4, arrival_time=t,
                prompt=[int(x) for x in rng.integers(1, 120, burst_in)]))
            flags.append(False)
    return reqs, flags


def _build(mode: str, cfg, params, ecfg, seed: int):
    if mode == "monolithic":
        from repro.serving.cluster import (EngineCluster,
                                           EngineClusterConfig)
        return EngineCluster(cfg, params, ecfg, EngineClusterConfig(
            n_engines=3, seed=seed))
    from repro.serving.disagg import DisaggCluster, DisaggConfig
    return DisaggCluster(cfg, params, ecfg, DisaggConfig(
        n_prefill=1, n_decode=2, link_gbps=32.0, seed=seed))


def _replay(system, requests):
    """Wall-clock replay that keeps the handles (``run()`` drops them):
    submit each request when its arrival time passes, pumping the
    cluster in between; drain at the end."""
    pending = sorted(requests, key=lambda r: r.arrival_time)
    handles = {}
    i = 0
    while i < len(pending) or system.busy():
        now = system.now()
        while i < len(pending) and pending[i].arrival_time <= now:
            handles[id(pending[i])] = system.submit(pending[i])
            i += 1
        if i < len(pending) and not system.busy():
            time.sleep(min(0.02, max(0.0,
                       pending[i].arrival_time - system.now())))
            continue
        system.step()
    system.drain()
    return [handles[id(r)] for r in requests]


def run_mode(mode: str, cfg, params, ecfg, quick: bool, seed: int):
    system = _build(mode, cfg, params, ecfg, seed)
    system.warmup()
    reqs, flags = _workload(quick, seed)
    handles = _replay(system, reqs)
    results = [h.result() for h in handles]
    stream = [r for r, s in zip(results, flags) if s]
    burst = [r for r, s in zip(results, flags) if not s]
    stream_tbts = [t for r in stream for t in r.tbts]
    merged, _ = system.metrics()
    sg = merged.sched_stats
    row = {
        "system": mode,
        "n_engines": 3,
        "completed": sum(r.finished for r in results),
        "submitted": len(results),
        "stream_p50_tbt_ms": round(
            1e3 * float(np.percentile(stream_tbts, 50)), 3),
        "stream_p99_tbt_ms": round(
            1e3 * float(np.percentile(stream_tbts, 99)), 3),
        "burst_p99_ttft_ms": round(1e3 * float(np.percentile(
            [r.ttft for r in burst], 99)), 3),
        "goodput_tok_s": round(merged.goodput_tokens_per_s(), 1),
        "handoffs": sg.get("handoffs", 0),
        "handoff_gb": sg.get("handoff_gb", 0.0),
        "handoff_wait_s": sg.get("handoff_wait_s", 0.0),
        "spilled_prefills": sg.get("spilled_prefills", 0),
        "prefill_util": sg.get("prefill_util", 0.0),
        "decode_util": sg.get("decode_util", 0.0),
        "chunked_prefills": sg.get("chunked_prefills", 0),
    }
    tokens = [list(r.tokens) for r in results]
    return row, tokens, all(r.finished for r in results)


def run(quick: bool = False, seed: int = 0):
    from repro.serving.engine import EngineConfig

    cfg, params = _model(seed)
    ecfg = EngineConfig(max_slots=4, max_len=320, n_lora_slots=8,
                        n_adapters=8, seed=seed)
    rows, toks, done = [], {}, {}
    for mode in ("monolithic", "disagg"):
        row, tokens, completed = run_mode(mode, cfg, params, ecfg,
                                          quick, seed)
        rows.append(row)
        toks[mode] = tokens
        done[mode] = completed
    identical = toks["monolithic"] == toks["disagg"]
    for r in rows:
        r["tokens_identical_to_monolithic"] = identical
    return rows, identical, all(done.values())


def validate(rows, identical=None, completed=None) -> dict:
    if identical is None:       # benchmarks.run path: recompute from rows
        identical = all(r["tokens_identical_to_monolithic"] for r in rows)
    if completed is None:
        completed = all(r["completed"] == r["submitted"] for r in rows)
    by = {r["system"]: r for r in rows}
    mono, dis = by["monolithic"], by["disagg"]
    return {
        "all_completed": bool(completed),
        "tokens_identical": bool(identical),
        "stream_p99_tbt_ms_monolithic": mono["stream_p99_tbt_ms"],
        "stream_p99_tbt_ms_disagg": dis["stream_p99_tbt_ms"],
        # The headline comparative claim — reported, not hard-gated:
        # on a noisy shared CI runner the tail ratio wobbles, while
        # completion + token identity are invariant.
        "stream_p99_tbt_improves": bool(
            dis["stream_p99_tbt_ms"] < mono["stream_p99_tbt_ms"]),
        "handoffs": dis["handoffs"],
        "handoff_gb": dis["handoff_gb"],
        "prefill_util": dis["prefill_util"],
        "decode_util": dis["decode_util"],
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write {name, paper_ref, rows, validated} "
                         "(CI schema)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rows, identical, completed = run(quick=args.quick, seed=args.seed)
    validated = validate(rows, identical, completed)
    for r in rows:
        print(r)
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, NAME, PAPER_REF, rows,
                                 validated))
    assert validated["all_completed"], "requests lost in the A/B"
    assert validated["tokens_identical"], (
        "disaggregation changed decoded tokens")
