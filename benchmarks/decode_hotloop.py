"""Decode hot-loop microbenchmark: fused device-resident loop vs seed.

A/Bs `ChameleonEngine`'s decode path (DESIGN §2) with the only
variable being ``EngineConfig.fused_hotloop``:

  seed  — one decode jit dispatch, (B, V) logits round-trip to a
          second sampling dispatch, per-step host re-uploads of the
          page table / active mask / sampling arrays, and a blocking
          token sync before any bookkeeping;
  fused — one donated-buffer jit dispatch per adaptive K-step horizon
          that fuses decode + sampling + cache_len advance with an
          on-device done-mask, device-resident batch state rebuilt only
          at batch epochs, and pipelined readback.

Reported per cell ({dense, paged} x {greedy, sampled} x {seed, fused},
plus a paged squash-continuation pair): hot-loop tokens/sec, decode
steps/sec, jit dispatches per token (``kernels.ops.DISPATCH_METER``),
the host-sync fraction of wall time, P50/P99 TBT, whether the streamed
tokens are identical to the seed loop's, and the donation memory probe
(the pre-step KV buffer must be *consumed* by the fused dispatch — no
double-buffered KV; the seed loop keeps it alive).

Emits the CI-checked BENCH JSON schema via ``--json`` (see
``benchmarks/check_json.py``); ``--quick`` shrinks the workload for
the bench-smoke job.

``--spec`` switches to the speculative-decoding A/B
(``name="spec_decode"``): the one variable is
``EngineConfig.spec_decode``, measured with a draft that is *exactly*
the target's first layer (the target's remaining layers have their
residual-writing projections zeroed, so draft and target logits are
bit-identical — acceptance 1.0 at 1/n_layers draft cost, the regime
speculation is built for). Reported per cell: tokens/sec, acceptance
rate, draft/verify/total dispatches per token, spec_k_eff, and
greedy token identity to the non-speculative loop.
"""
from __future__ import annotations

import time

import numpy as np

NAME = "decode_hotloop"
SPEC_NAME = "spec_decode"
PAPER_REF = "Chameleon hot path; S-LoRA (arXiv 2311.03285) unified memory"


def _engine(cfg, params, *, fused, paged, seed=0, max_slots=4,
            max_len=384, spec=False, draft=None, catalog=None):
    from repro.serving.engine import ChameleonEngine, EngineConfig

    # Async loading and the prefetchers are pinned off so both loops
    # place requests on identical steps (their own A/B is fig10
    # --loading); the A/B's one variable is the hot loop.
    return ChameleonEngine(cfg, params, EngineConfig(
        max_slots=max_slots, max_len=max_len, n_lora_slots=4,
        n_adapters=4, seed=seed, paged=paged, fused_hotloop=fused,
        async_load=False, queued_prefetch=False,
        histogram_prefetch=False, spec_decode=spec),
        draft=draft, catalog=catalog)


def _drain(eng, max_steps=200_000):
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return steps


def _probe_donation(eng):
    """Dispatch one decode step and check whether it *consumed* the KV
    buffer (jit donation → in-place update, no second KV allocation).
    The fused loop donates; the seed loop's un-donated dispatch keeps
    the input alive alongside its output — the double buffering this
    PR removes."""
    kv_before = eng.kv_pages[0] if eng.paged else eng.kv[0]
    eng.step()
    return bool(kv_before.is_deleted())


def run_cell(cfg, params, *, paged, sampled, fused, output_len,
             seed=0):
    """One measured drain of a full batch of long decodes (queue kept
    empty so the fused loop's micro-horizon engages — the hot loop this
    benchmark isolates). Returns the row dict + the streamed tokens."""
    from repro.core import Request, SamplingParams
    from repro.kernels.ops import DISPATCH_METER

    eng = _engine(cfg, params, fused=fused, paged=paged, seed=seed)
    sp = (SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                         seed=seed + 1) if sampled else None)
    B = eng.ecfg.max_slots

    # Warmup: compile prefill + every decode/horizon jit variant the
    # measured phase uses, then reset accounting.
    warm = [eng.submit(Request(input_len=16, output_len=3 * 8,
                               adapter_id=i), sampling=sp)
            for i in range(B)]
    _drain(eng)
    assert all(len(h.tokens) == 3 * 8 for h in warm)

    # Best-of-2 measured drains (identical token streams, asserted):
    # one full batch of long decodes each; the min wall damps shared-
    # runner noise without changing what is measured.
    tokens = wall = steps = tbts = n_disp = sync_s = None
    for _ in range(2):
        eng.reset_stats()
        handles = [eng.submit(Request(input_len=16,
                                      output_len=output_len,
                                      adapter_id=i), sampling=sp)
                   for i in range(B)]
        DISPATCH_METER.reset()
        t0 = time.perf_counter()
        n_steps = _drain(eng)
        w = time.perf_counter() - t0
        toks = [h.tokens for h in handles]
        assert tokens is None or toks == tokens, "non-deterministic run"
        if wall is None or w < wall:
            tokens, wall, steps = toks, w, n_steps
            n_disp = DISPATCH_METER.dispatches
            sync_s = DISPATCH_METER.sync_seconds
            tbts = [tbt for h in handles for tbt in h.result().tbts]
    n_tok = sum(len(t) for t in tokens)
    assert n_tok == B * output_len, "truncated run"

    # Donation probe on a fresh single-request batch (the measured
    # engine is drained; probing mid-run would skew timings).
    probe = eng.submit(Request(input_len=16, output_len=16,
                               adapter_id=0), sampling=sp)
    while not eng.active.any():
        eng.step()
    donated = _probe_donation(eng)
    eng.drain()
    assert probe.done

    row = {
        "mode": ("fused" if fused else "seed"),
        "kv": ("paged" if paged else "dense"),
        "sampling": ("sampled" if sampled else "greedy"),
        "tokens": n_tok,
        "wall_s": round(wall, 4),
        "tokens_per_sec": round(n_tok / wall, 2),
        "decode_steps_per_sec": round(n_tok / eng.ecfg.max_slots / wall,
                                      2),
        "engine_steps": steps,
        "dispatches_per_token": round(n_disp / n_tok, 4),
        "host_sync_fraction": round(min(sync_s / wall, 1.0), 4),
        "p50_tbt_ms": round(1e3 * float(np.percentile(tbts, 50)), 3),
        "p99_tbt_ms": round(1e3 * float(np.percentile(tbts, 99)), 3),
        "kv_donated": donated,
    }
    return row, tokens


def run_squash_cell(cfg, params, *, fused, output_len, seed=0):
    """Squash continuation: steal the page pool mid-decode to force a
    preemption, restore it, and check the re-executed stream. The
    final tokens must be loop-independent (and the fused run must
    still preempt — its horizon clamps to allocated pages instead of
    allocating ahead)."""
    from repro.core import Request

    eng = _engine(cfg, params, fused=fused, paged=True, seed=seed)
    h = eng.submit(Request(input_len=16, output_len=output_len,
                           adapter_id=0))
    it = h.stream()
    for _ in range(4):
        next(it)
    stolen, eng.free_pages = eng.free_pages, []
    for _ in range(60):
        eng.step()
        if eng.n_preempted:
            break
    preempted = eng.n_preempted
    eng.free_pages = stolen
    eng.drain()
    row = {
        "mode": ("fused" if fused else "seed"),
        "kv": "paged",
        "sampling": "greedy-squash",
        "tokens": len(h.tokens),
        "preempted": preempted,
        "squashes": h.req.squash_count,
    }
    return row, [h.tokens]


def _shared_layer_draft(cfg, params):
    """Build the measurement pair for the spec A/B.

    Zero the residual-writing projections (attention ``o``, MLP
    ``down``) of every target layer but the first: those layers then
    add exact zeros to the residual stream, so the target's logits are
    computed entirely by layer 0 + embeddings + head. The draft is a
    1-layer config sharing exactly those parameters — its logits are
    bit-identical to the target's, acceptance is 1.0 by construction,
    and a draft step costs 1/n_layers of a target step. This isolates
    the *mechanism* speedup (fewer target dispatches per token) from
    draft quality, which is model-dependent.
    """
    from dataclasses import replace

    tparams = dict(params)
    for k in ("layers/o", "layers/down"):
        tparams[k] = tparams[k].at[1:].set(0.0)
    dcfg = replace(cfg, n_layers=1)
    dparams = {k: (v[:1] if k.startswith("layers/") else v)
               for k, v in tparams.items()}
    return tparams, dcfg, dparams


def _zeroed_catalog(cfg, n_adapters=4, r_max=32):
    """LoRA adapters with zero delta: the adapter-free draft then sees
    the same logits path as the adapter-applied target."""
    import jax.numpy as jnp

    from repro.serving.engine import AdapterCatalog

    cat = AdapterCatalog(cfg, n_adapters, r_max, seed=0)
    for aid in cat.weights:
        cat.weights[aid] = {
            k: (jnp.zeros_like(a), jnp.zeros_like(b))
            for k, (a, b) in cat.weights[aid].items()}
    return cat


def run_spec_cell(cfg, params, *, spec, paged, sampled, draft,
                  output_len, seed=0):
    """One measured drain with/without speculation (both engines run
    the fused loop; ``spec_decode`` is the A/B's only variable)."""
    from repro.core import Request, SamplingParams
    from repro.kernels.ops import DISPATCH_METER

    eng = _engine(cfg, params, fused=True, paged=paged, seed=seed,
                  spec=spec, draft=draft if spec else None,
                  catalog=_zeroed_catalog(cfg))
    sp = (SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                         seed=seed + 1) if sampled else None)
    B = eng.ecfg.max_slots

    warm = [eng.submit(Request(input_len=16, output_len=3 * 8,
                               adapter_id=i), sampling=sp)
            for i in range(B)]
    _drain(eng)
    assert all(len(h.tokens) == 3 * 8 for h in warm)

    tokens = wall = n_disp = n_draft = n_verify = st = None
    for _ in range(2):
        eng.reset_stats()
        handles = [eng.submit(Request(input_len=16,
                                      output_len=output_len,
                                      adapter_id=i), sampling=sp)
                   for i in range(B)]
        DISPATCH_METER.reset()
        t0 = time.perf_counter()
        _drain(eng)
        w = time.perf_counter() - t0
        toks = [h.tokens for h in handles]
        assert tokens is None or toks == tokens, "non-deterministic run"
        if wall is None or w < wall:
            tokens, wall = toks, w
            n_disp = DISPATCH_METER.dispatches
            n_draft = DISPATCH_METER.draft_dispatches
            n_verify = DISPATCH_METER.verify_dispatches
            st = eng.spec_stats()
    n_tok = sum(len(t) for t in tokens)
    assert n_tok == B * output_len, "truncated run"

    row = {
        "mode": ("spec" if spec else "nonspec"),
        "kv": ("paged" if paged else "dense"),
        "sampling": ("sampled" if sampled else "greedy"),
        "tokens": n_tok,
        "wall_s": round(wall, 4),
        "tokens_per_sec": round(n_tok / wall, 2),
        "dispatches_per_token": round(n_disp / n_tok, 4),
        "draft_dispatches_per_token": round(n_draft / n_tok, 4),
        "verify_dispatches_per_token": round(n_verify / n_tok, 4),
        "spec_accept_rate": st.get("spec_accept_rate", 0.0),
        "spec_k_eff": st.get("spec_k_eff", 0),
    }
    return row, tokens


def run_spec(quick: bool = False, seed: int = 0):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import api as model_api

    # Compute-weighted sizing (vs the dispatch-bound config above):
    # speculation trades per-token *target* forwards for cheap draft
    # forwards plus one batched verify, so the target step must carry
    # real compute for the trade to show.
    cfg = get_config("chameleon-llama-7b").reduced(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=1024)
    base = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                 jnp.float32)
    params, dcfg, dparams = _shared_layer_draft(cfg, base)
    # Guard the construction: draft argmax must equal target argmax.
    probe = jax.random.randint(jax.random.PRNGKey(seed + 2), (2, 12),
                               0, cfg.vocab_size)
    tl, _ = model_api.prefill(cfg, params, probe)
    dl, _ = model_api.prefill(dcfg, dparams, probe)
    assert (jnp.argmax(tl, -1) == jnp.argmax(dl, -1)).all(), (
        "shared-layer draft is not logit-identical to the target")
    output_len = 96 if quick else 192
    draft = (dcfg, dparams)

    rows = []
    greedy_identical = True
    for paged in (False, True):
        for sampled in (False, True):
            pair = {}
            for spec in (False, True):
                row, toks = run_spec_cell(
                    cfg, params, spec=spec, paged=paged,
                    sampled=sampled, draft=draft,
                    output_len=output_len, seed=seed)
                pair[spec] = (row, toks)
            # Greedy speculation is bit-identical by construction;
            # sampled speculation is distribution-preserving (rejection
            # sampling), deterministic per seed but not token-identical
            # to the non-speculative sampler — so identity is asserted
            # on the greedy cells only.
            same = (pair[True][1] == pair[False][1]) if not sampled \
                else None
            if not sampled:
                greedy_identical &= same
            for spec in (False, True):
                pair[spec][0]["tokens_identical_to_nonspec"] = same
                rows.append(pair[spec][0])
    return rows, greedy_identical


def validate_spec(rows, greedy_identical) -> dict:
    def mean_over(mode, field):
        xs = [r[field] for r in rows if r["mode"] == mode]
        return float(np.mean(xs))

    speedup = (mean_over("spec", "tokens_per_sec")
               / mean_over("nonspec", "tokens_per_sec"))
    spec_rows = [r for r in rows if r["mode"] == "spec"]
    accept = float(np.mean([r["spec_accept_rate"] for r in spec_rows]))
    return {
        # Acceptance gates (ISSUE 10): greedy token identity, >=1.3x
        # decode throughput at high acceptance, and the dispatch
        # accounting that explains it.
        "tokens_identical": bool(greedy_identical),
        "spec_accept_rate": round(accept, 4),
        "speedup_tokens_per_sec": round(speedup, 2),
        "speedup_ge_1_3x": bool(speedup >= 1.3),
        "dispatches_per_token_nonspec": round(
            mean_over("nonspec", "dispatches_per_token"), 4),
        "dispatches_per_token_spec": round(
            mean_over("spec", "dispatches_per_token"), 4),
        "draft_dispatches_per_token": round(
            mean_over("spec", "draft_dispatches_per_token"), 4),
        "verify_dispatches_per_token": round(
            mean_over("spec", "verify_dispatches_per_token"), 4),
        "spec_k_eff": float(np.mean([r["spec_k_eff"]
                                     for r in spec_rows])),
    }


def run(quick: bool = False, seed: int = 0):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import api as model_api

    # A deliberately dispatch-bound config: this benchmark isolates
    # the hot loop's *host overhead* (dispatches, logits round-trips,
    # re-uploads, blocking syncs), which is what the fused loop
    # removes — per-token model compute is identical across both loops
    # by construction (and asserted by the token-identity A/B; the
    # parity suite covers the standard reduced config).
    cfg = get_config("chameleon-llama-7b").reduced(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=128)
    params = model_api.init_params(cfg, jax.random.PRNGKey(seed),
                                   jnp.float32)
    output_len = 128 if quick else 256

    rows = []
    identical = True
    for paged in (False, True):
        for sampled in (False, True):
            pair = {}
            for fused in (False, True):
                row, toks = run_cell(cfg, params, paged=paged,
                                     sampled=sampled, fused=fused,
                                     output_len=output_len, seed=seed)
                pair[fused] = (row, toks)
            same = pair[True][1] == pair[False][1]
            identical &= same
            for fused in (False, True):
                pair[fused][0]["tokens_identical_to_seed"] = same
                pair[fused][0]["preempted"] = 0
                pair[fused][0]["squashes"] = 0
                rows.append(pair[fused][0])
    # Squash-continuation pair (paged, greedy).
    sq = {}
    for fused in (False, True):
        row, toks = run_squash_cell(cfg, params, fused=fused,
                                    output_len=3 * 64, seed=seed)
        sq[fused] = (row, toks)
    same = sq[True][1] == sq[False][1]
    identical &= same
    for fused in (False, True):
        r = sq[fused][0]
        r.update({
            "wall_s": 0.0, "tokens_per_sec": 0.0,
            "decode_steps_per_sec": 0.0, "engine_steps": 0,
            "dispatches_per_token": 0.0, "host_sync_fraction": 0.0,
            "p50_tbt_ms": 0.0, "p99_tbt_ms": 0.0, "kv_donated": fused,
            "tokens_identical_to_seed": same,
        })
        rows.append(r)
    return rows, identical


def validate(rows, identical) -> dict:
    def mean_over(mode, field, pred=lambda r: True):
        xs = [r[field] for r in rows
              if r["mode"] == mode and r["tokens_per_sec"] > 0
              and pred(r)]
        return float(np.mean(xs))

    speedup = (mean_over("fused", "tokens_per_sec")
               / mean_over("seed", "tokens_per_sec"))
    d_seed = mean_over("seed", "dispatches_per_token")
    d_fused = mean_over("fused", "dispatches_per_token")
    fused_rows = [r for r in rows if r["mode"] == "fused"
                  and r["tokens_per_sec"] > 0]
    squash = [r for r in rows if r["sampling"] == "greedy-squash"]
    return {
        # The acceptance gates (ISSUE 5): token identity everywhere,
        # >=2x hot-loop throughput, >=2x fewer dispatches per token,
        # and no double-buffered KV (donation verified by the probe).
        "tokens_identical": bool(identical),
        "speedup_tokens_per_sec": round(speedup, 2),
        "speedup_ge_2x": bool(speedup >= 2.0),
        "dispatches_per_token_seed": round(d_seed, 3),
        "dispatches_per_token_fused": round(d_fused, 3),
        "dispatch_ratio": round(d_seed / d_fused, 2),
        "dispatch_ratio_ge_2x": bool(d_seed / d_fused >= 2.0),
        "kv_donated": all(r["kv_donated"] for r in fused_rows),
        "host_sync_fraction_seed": round(
            mean_over("seed", "host_sync_fraction"), 4),
        "host_sync_fraction_fused": round(
            mean_over("fused", "host_sync_fraction"), 4),
        "squash_preempted_both": all(r["preempted"] >= 1
                                     for r in squash),
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding A/B instead of the "
                         "fused-vs-seed A/B")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write {name, paper_ref, rows, validated} "
                         "(CI schema)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.spec:
        rows, identical = run_spec(quick=args.quick, seed=args.seed)
        validated = validate_spec(rows, identical)
        name = SPEC_NAME
    else:
        rows, identical = run(quick=args.quick, seed=args.seed)
        validated = validate(rows, identical)
        name = NAME
    for r in rows:
        print(r)
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, name, PAPER_REF, rows,
                                 validated))
    assert validated["tokens_identical"], (
        "speculation changed greedy tokens" if args.spec
        else "fused hot loop changed decoded tokens")
