"""Fig. 15: histogram-based predictive prefetching on top of the cache.

S-LoRA vs Chameleon vs Chameleon+Prefetch at medium load, per-rank P99
TTFT. Paper: prefetch adds ~8.8 % P99 reduction over Chameleon; the
workload's power-law/uniform structure makes arrival prediction easy.
"""
from __future__ import annotations

from .common import LOAD_MED, run_system

NAME = "fig15_prefetch"
PAPER_REF = "Figure 15"

SYSTEMS = ("slora", "chameleon", "chameleon-prefetch")


def run(quick: bool = False):
    duration = 60.0 if quick else 180.0
    rows = []
    for system in SYSTEMS:
        m, sim, cost, trace = run_system(system, LOAD_MED,
                                         duration=duration)
        for rank, v in m.per_rank_p99_ttft().items():
            rows.append({"system": system, "rank": rank, "p99_ttft": v})
        rows.append({"system": system, "rank": "all",
                     "p99_ttft": m.p99_ttft(),
                     "hit_rate": m.cache_stats.get("hit_rate", 0.0)})
    return rows


def validate(rows) -> dict:
    overall = {r["system"]: r["p99_ttft"] for r in rows
               if r["rank"] == "all"}
    hit = {r["system"]: r.get("hit_rate") for r in rows
           if r["rank"] == "all"}
    return {
        "prefetch_extra_reduction": round(
            1 - overall["chameleon-prefetch"] / overall["chameleon"], 3),
        "paper_extra_reduction": 0.088,
        "hit_rates": hit,
    }


if __name__ == "__main__":
    print(validate(run(quick=True)))
