"""Gateway overload A/B: heavy-hitter tenant with and without the
multi-tenant gateway (DESIGN §3.3, ROADMAP item 4).

One adversarial tenant floods the node at ~8x every other tenant's
rate with ~4x longer decodes (``synthesize_multitenant``). The A arm
submits the combined trace straight into the DES node — the engine
scheduler is adapter-aware but tenant-blind, so the flood inflates
every tenant's queueing delay. The B arm submits the *identical* trace
through ``serving.gateway.Gateway``: per-tenant queue caps, start-time
fair queueing, and SLO-aware reject/degrade bound the flood at the
front door.

Claims validated (consumed by the gateway-smoke CI job via
``check_json``):

- ``all_completed``          every submit in both arms reached a
                             terminal handle state — nothing dropped
                             silently;
- ``decision_trace_complete``the gateway arm has one GatewayDecision
                             per submit, whatever the outcome;
- ``fair_tenant_p99_improves`` pooled P99 TTFT of the well-behaved
                             tenants' finished requests is lower with
                             the gateway at identical offered load;
- ``heavy_hitter_bounded``   the flood's share of completed decode
                             tokens shrinks under the gateway.

Usage: ``python -m benchmarks.gateway_overload [--quick] [--json PATH]``
"""
from __future__ import annotations

import numpy as np

from repro.core import Request, RequestState
from repro.serving import (GatewayConfig, NodeConfig, TenantPolicy,
                           TraceConfig, build_node, build_system,
                           synthesize_multitenant)
from repro.serving.gateway import Gateway

NAME = "gateway"
PAPER_REF = "ROADMAP item 4 / DESIGN §3.3 (production front door)"

WELL_BEHAVED = ("acme", "globex", "initech", "umbrella")
HEAVY = "floodcorp"


def _trace(quick: bool):
    """The combined multi-tenant trace (fresh Request objects per call
    so the two arms never share mutable state)."""
    cfg = TraceConfig(rps=0.5 if quick else 0.8,
                      duration_s=30.0 if quick else 120.0,
                      n_adapters=32, seed=11)
    _, adapters, _ = build_node("chameleon", NodeConfig(n_adapters=32))
    return synthesize_multitenant(cfg, list(adapters.values()),
                                  tenants=WELL_BEHAVED,
                                  heavy_hitter=HEAVY)


def _gateway_cfg(quick: bool) -> GatewayConfig:
    return GatewayConfig(
        default_policy=TenantPolicy(weight=1.0, max_inflight=16,
                                    max_queued=48),
        dispatch_pressure_max=48.0,
        max_queued_total=256,
        slo_default_s=30.0 if quick else 60.0,
        service_parallelism=16.0,
    )


def _replay(system, trace, via_gateway: bool):
    """Submit the whole trace (arrival times are honoured by the DES /
    the gateway's release heap), run dry, return the handles."""
    handles = []
    for req in trace.requests:
        if via_gateway:
            handles.append(system.submit(req.tenant, req))
        else:
            handles.append(system.submit(req))
    system.drain()
    return handles


def _tenant_rows(mode: str, handles, decisions) -> list[dict]:
    by_tenant: dict[str, list] = {}
    for h in handles:
        by_tenant.setdefault(h.req.tenant, []).append(h)
    total_tokens = sum(len(h.tokens) for h in handles) or 1
    rows = []
    for tenant in (*WELL_BEHAVED, HEAVY):
        hs = by_tenant.get(tenant, [])
        ttfts = [h.req.ttft() for h in hs
                 if h.state is RequestState.FINISHED
                 and h.req.ttft() is not None]
        tokens = sum(len(h.tokens) for h in hs)
        degraded = sum(1 for h in hs if h.decision is not None
                       and h.decision.action == "degrade")
        rows.append({
            "mode": mode, "tenant": tenant, "heavy": tenant == HEAVY,
            "submitted": len(hs),
            "finished": sum(h.state is RequestState.FINISHED for h in hs),
            "rejected": sum(h.state is RequestState.REJECTED for h in hs),
            "expired": sum(h.state is RequestState.EXPIRED for h in hs),
            "degraded": degraded,
            "p50_ttft_s": float(np.percentile(ttfts, 50)) if ttfts else -1.0,
            "p99_ttft_s": float(np.percentile(ttfts, 99)) if ttfts else -1.0,
            "tokens_done": tokens,
            "token_share": tokens / total_tokens,
        })
    return rows


def run_ab(quick: bool = False):
    # A: no gateway — the flood hits the tenant-blind scheduler.
    base = build_system("chameleon", tier="sim",
                        node=NodeConfig(n_adapters=32, seed=3))
    base_handles = _replay(base, _trace(quick), via_gateway=False)

    # B: identical offered load through the gateway.
    gw = build_system("chameleon", tier="sim",
                      node=NodeConfig(n_adapters=32, seed=3),
                      gateway=_gateway_cfg(quick))
    gw_handles = _replay(gw, _trace(quick), via_gateway=True)

    rows = (_tenant_rows("nogateway", base_handles, None)
            + _tenant_rows("gateway", gw_handles, gw.decisions))
    return rows, base_handles, gw_handles, gw


def _pooled_p99(rows, mode):
    """Pooled fair-tenant P99: weight each tenant row by its finished
    count (rows carry per-tenant percentiles; the pooled figure is
    recomputed from the worst tenant to stay conservative)."""
    vals = [r["p99_ttft_s"] for r in rows
            if r["mode"] == mode and not r["heavy"] and r["p99_ttft_s"] >= 0]
    return max(vals) if vals else float("inf")


def validate(rows, base_handles, gw_handles, gw: Gateway) -> dict:
    all_terminal = (all(h.done for h in base_handles)
                    and all(h.done for h in gw_handles))
    trace_complete = all(h.req.req_id in gw.decisions for h in gw_handles)
    p99_base = _pooled_p99(rows, "nogateway")
    p99_gw = _pooled_p99(rows, "gateway")
    share_base = next(r["token_share"] for r in rows
                      if r["mode"] == "nogateway" and r["heavy"])
    share_gw = next(r["token_share"] for r in rows
                    if r["mode"] == "gateway" and r["heavy"])
    fair_finished = all(
        r["finished"] == r["submitted"] for r in rows
        if r["mode"] == "gateway" and not r["heavy"])
    return {
        "all_completed": bool(all_terminal),
        "decision_trace_complete": bool(trace_complete),
        "fair_tenant_p99_improves": bool(p99_gw < p99_base),
        "fair_tenants_all_finished": bool(fair_finished),
        "heavy_hitter_bounded": bool(share_gw < share_base),
        "worst_fair_p99_ttft_nogateway_s": p99_base,
        "worst_fair_p99_ttft_gateway_s": p99_gw,
        "heavy_token_share_nogateway": share_base,
        "heavy_token_share_gateway": share_gw,
        "gw_rejected": gw.n_rejected,
        "gw_degraded": gw.n_degraded,
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {name, paper_ref, rows, validated} "
                         "to PATH (CI schema)")
    args = ap.parse_args()
    rows, bh, gh, gw = run_ab(quick=args.quick)
    validated = validate(rows, bh, gh, gw)
    for r in rows:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, NAME, PAPER_REF, rows,
                                 validated))
