"""Assert benchmark result JSONs match the CI schema.

Usage: ``python -m benchmarks.check_json results/*.json``

Schema (written by ``common.emit_json``): a document is an object with
``name`` (str), ``paper_ref`` (str), ``rows`` (non-empty list of flat
dicts with consistent keys and JSON-scalar/list values), ``validated``
(dict of derived claims). Exit code is non-zero on any violation, so
the bench-smoke CI job fails when an entrypoint silently changes its
output shape.
"""
from __future__ import annotations

import json
import sys

SCALARS = (str, int, float, bool, type(None))

# Benchmarks whose ``validated`` dict the CI jobs consume by key: a
# missing key here means an entrypoint silently dropped an acceptance
# claim, which must fail the schema check, not the consumer.
REQUIRED_VALIDATED = {
    "decode_hotloop": {
        "tokens_identical", "speedup_tokens_per_sec", "speedup_ge_2x",
        "dispatch_ratio", "dispatch_ratio_ge_2x", "kv_donated",
        "host_sync_fraction_seed", "host_sync_fraction_fused",
    },
    "spec_decode": {
        "tokens_identical", "spec_accept_rate",
        "speedup_tokens_per_sec", "speedup_ge_1_3x",
        "dispatches_per_token_nonspec", "dispatches_per_token_spec",
    },
    "fig10_latency_load_paged_ab": {"all_completed", "tokens_identical"},
    "fig10_latency_load_spec_ab": {
        "all_completed", "tokens_identical", "spec_accept_rate"},
    "fig10_latency_load_loading_ab": {
        "all_completed", "overlap_beats_sync_p99_ttft"},
    "fig10_latency_load_hotloop_ab": {"all_completed",
                                      "tokens_identical"},
    "fig10_latency_load_prefix_ab": {
        "all_completed", "tokens_identical", "prefix_hit_rate",
        "prefix_reduces_p99_ttft"},
    "fig17_scalability_sharded_engine": {
        "all_completed", "tokens_identical", "mesh_shape", "n_devices",
        "throughput_ratio_mesh_over_single", "collective_frac"},
    "gateway": {"all_completed", "fair_tenant_p99_improves"},
    "disagg_interference": {"all_completed", "tokens_identical",
                            "handoffs", "prefill_util", "decode_util"},
}


def _flat(d: dict, what: str) -> list[str]:
    errs = []
    for k, v in d.items():
        if not isinstance(k, str):
            errs.append(f"{what}: non-string key {k!r}")
        if isinstance(v, dict):
            errs.append(f"{what}[{k}]: nested dict not allowed")
        elif isinstance(v, list):
            if not all(isinstance(x, SCALARS) for x in v):
                errs.append(f"{what}[{k}]: list of non-scalars")
        elif not isinstance(v, SCALARS):
            errs.append(f"{what}[{k}]: bad value type {type(v).__name__}")
    return errs


def check_doc(doc, path: str) -> list[str]:
    errs = []
    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    for key, typ in (("name", str), ("paper_ref", str), ("rows", list),
                     ("validated", dict)):
        if not isinstance(doc.get(key), typ):
            errs.append(f"{path}: missing/mistyped key {key!r} "
                        f"(want {typ.__name__})")
    if errs:
        return errs
    if not doc["rows"]:
        errs.append(f"{path}: rows is empty")
        return errs
    keys0 = set(doc["rows"][0])
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict):
            errs.append(f"{path}: rows[{i}] is not an object")
            continue
        if set(row) != keys0:
            errs.append(f"{path}: rows[{i}] keys {sorted(set(row))} "
                        f"differ from rows[0] keys {sorted(keys0)}")
        errs.extend(_flat(row, f"{path}: rows[{i}]"))
    errs.extend(_flat(doc["validated"], f"{path}: validated"))
    required = REQUIRED_VALIDATED.get(doc["name"], set())
    missing = required - set(doc["validated"])
    if missing:
        errs.append(f"{path}: validated missing required keys "
                    f"{sorted(missing)}")
    return errs


def main(paths: list[str]) -> int:
    if not paths:
        print("usage: python -m benchmarks.check_json FILE.json ...",
              file=sys.stderr)
        return 2
    failures = []
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            failures.append(f"{path}: unreadable ({exc})")
            continue
        errs = check_doc(doc, path)
        failures.extend(errs)
        if not errs:
            print(f"ok: {path} ({doc['name']}, {len(doc['rows'])} rows)")
    for msg in failures:
        print("SCHEMA ERROR:", msg, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
