"""Paper-figure benchmark harness (one module per table/figure)."""
