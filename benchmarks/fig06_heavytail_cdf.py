"""Fig. 6: CDF of TTFT / E2E latency, requests executed one-by-one.

The paper's point: production requests are heavy-tailed, and adding
LoRA adapters (load + compute) stretches the tail further. We execute
the trace's requests in isolation via the cost model, with and without
adapters.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_adapter_pool
from repro.serving.cost_model import A40, LLAMA_7B, CostModel
from repro.serving.trace import TraceConfig, synthesize

NAME = "fig06_heavytail_cdf"
PAPER_REF = "Figure 6"


def run(quick: bool = False):
    cost = CostModel(hw=A40, model=LLAMA_7B)
    pool = build_adapter_pool(100, LLAMA_7B.d_model, LLAMA_7B.n_layers,
                              LLAMA_7B.kv_bytes_per_token)
    cfg = TraceConfig(rps=8.0, duration_s=30.0 if quick else 120.0, seed=3)
    trace = synthesize(cfg, pool)
    by_id = {a.adapter_id: a for a in pool}
    rows = []
    for r in trace.requests:
        rank = by_id[r.adapter_id].rank
        rows.append({
            "ttft_base": cost.isolated_ttft(r.input_len, 0,
                                            cold_adapter=False),
            "ttft_lora": cost.isolated_ttft(r.input_len, rank),
            "e2e_base": cost.isolated_time(r.input_len, r.output_len, 0,
                                           cold_adapter=False),
            "e2e_lora": cost.isolated_time(r.input_len, r.output_len,
                                           rank),
            "rank": rank,
        })
    return rows


def validate(rows) -> dict:
    t = np.array([r["e2e_lora"] for r in rows])
    tb = np.array([r["ttft_lora"] for r in rows])
    tb0 = np.array([r["ttft_base"] for r in rows])
    return {
        "e2e_p99_over_p50": round(float(np.percentile(t, 99)
                                        / np.percentile(t, 50)), 2),
        "ttft_tail_stretch_lora": round(
            float(np.percentile(tb, 99) / np.percentile(tb0, 99)), 3),
        "claim": "heavy tail (p99/p50 >> 1); LoRA stretches the tail",
    }


if __name__ == "__main__":
    rows = run(quick=True)
    print(len(rows), "requests")
    print(validate(rows))
