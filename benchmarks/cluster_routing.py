"""Beyond-paper: cluster-level routing × Chameleon node caches.

The paper (§6) positions Chameleon as complementary to cluster
schedulers. This benchmark quantifies the composition: 4 Chameleon
nodes at 4× single-node high load under three routers. Adapter-affinity
routing concentrates each adapter's requests where its weights are
already cached — node-level caching is what makes the policy pay.
"""
from __future__ import annotations

from repro.serving.cluster import run_cluster

NAME = "cluster_routing"
PAPER_REF = "beyond-paper (paper §6 composition claim)"


def run(quick: bool = False):
    duration = 60.0 if quick else 90.0
    rows = []
    for system in ("chameleon",) if quick else ("chameleon", "slora"):
        for policy in ("round_robin", "least_loaded", "adapter_affinity"):
            m, per = run_cluster(policy, rps=48.0, n_nodes=4,
                                 duration=duration, system=system)
            rows.append({
                "system": system, "policy": policy,
                "p50_ttft": m.p50_ttft(), "p99_ttft": m.p99_ttft(),
                "hit_rate": m.cache_stats["hit_rate"],
                "gb_loaded": m.cache_stats["gb_loaded"],
            })
    return rows


def validate(rows) -> dict:
    cham = {r["policy"]: r for r in rows if r["system"] == "chameleon"}
    return {
        "affinity_p99_vs_round_robin": round(
            cham["adapter_affinity"]["p99_ttft"]
            / cham["round_robin"]["p99_ttft"], 3),
        "affinity_hit_rate": round(cham["adapter_affinity"]["hit_rate"], 3),
        "round_robin_hit_rate": round(cham["round_robin"]["hit_rate"], 3),
    }


if __name__ == "__main__":
    rows = run(quick=True)
    for r in rows:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validate(rows))
