"""Beyond-paper: cluster-level routing × Chameleon node caches.

The paper (§6) positions Chameleon as complementary to cluster
schedulers. This benchmark quantifies the composition in both data
planes (DESIGN §3):

- default: 4 DES Chameleon nodes at 4× single-node high load under the
  routing policies — production scale, seconds of wall time;
- ``--real-engine``: N≥2 real ``ChameleonEngine`` replicas (jit'd JAX
  prefill/decode on a reduced model) replaying a downscaled shared
  trace against the wall clock. Adapter-affinity routing concentrates
  each adapter's requests where its weights are already cached, so it
  must beat random routing on adapter loads (cache misses) while
  keeping tail TTFT competitive.
"""
from __future__ import annotations

from repro.serving.cluster import run_cluster

NAME = "cluster_routing"
PAPER_REF = "beyond-paper (paper §6 composition claim)"

ENGINE_POLICIES = ("random", "least_loaded", "adapter_affinity")


def run(quick: bool = False):
    duration = 60.0 if quick else 90.0
    rows = []
    for system in ("chameleon",) if quick else ("chameleon", "slora"):
        for policy in ("round_robin", "least_loaded", "adapter_affinity"):
            m, per = run_cluster(policy, rps=48.0, n_nodes=4,
                                 duration=duration, system=system)
            rows.append({
                "system": system, "policy": policy,
                "p50_ttft": m.p50_ttft(), "p99_ttft": m.p99_ttft(),
                "hit_rate": m.cache_stats["hit_rate"],
                "gb_loaded": m.cache_stats["gb_loaded"],
            })
    return rows


def validate(rows) -> dict:
    cham = {r["policy"]: r for r in rows if r["system"] == "chameleon"}
    return {
        "affinity_p99_vs_round_robin": round(
            cham["adapter_affinity"]["p99_ttft"]
            / cham["round_robin"]["p99_ttft"], 3),
        "affinity_hit_rate": round(cham["adapter_affinity"]["hit_rate"], 3),
        "round_robin_hit_rate": round(cham["round_robin"]["hit_rate"], 3),
    }


# ------------------------------------------------------------------
# Real-engine mode: the same Router drives N ChameleonEngine replicas.
# ------------------------------------------------------------------
def run_real_engine(n_engines: int = 2, quick: bool = True,
                    system: str = "chameleon", seed: int = 0):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.serving.cluster import EngineCluster, EngineClusterConfig
    from repro.serving.engine import EngineConfig
    from repro.serving.trace import (TraceConfig, downscale_for_engine,
                                     synthesize)
    from repro.core.lora import build_adapter_pool
    from repro.models import api

    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(max_slots=4, max_len=128, n_lora_slots=3,
                        n_adapters=12, seed=seed)

    # Production-shaped trace, downscaled onto the reduced engine:
    # heavy-tailed lengths + power-law adapter popularity survive the
    # rescale, which is what the routing policies react to.
    rps, duration = (16.0, 4.0) if quick else (24.0, 8.0)
    tcfg = TraceConfig(rps=rps, duration_s=duration,
                       n_adapters=ecfg.n_adapters, seed=seed)
    pool = build_adapter_pool(ecfg.n_adapters, 64, 4, 64)
    base = synthesize(tcfg, pool)

    rows = []
    for policy in ENGINE_POLICIES:
        trace = downscale_for_engine(base, ecfg.n_adapters,
                                     max_input=48, max_output=16,
                                     time_scale=1.0)
        cluster = EngineCluster(
            cfg, params, ecfg,
            EngineClusterConfig(n_engines=n_engines, system=system,
                                policy=policy, seed=seed))
        cluster.warmup()
        merged, per = cluster.run(trace.requests)
        rows.append({
            "system": system, "policy": policy,
            "n_engines": n_engines,
            "completed": merged.completed(),
            "p50_ttft": merged.p50_ttft(),
            "p99_ttft": merged.p99_ttft(),
            "hit_rate": merged.cache_stats["hit_rate"],
            "adapter_loads": merged.cache_stats["misses"],
            "routed": cluster.routed.tolist(),
        })
    return rows


def validate_real_engine(rows) -> dict:
    by = {r["policy"]: r for r in rows}
    return {
        "affinity_loads_vs_random": round(
            by["adapter_affinity"]["adapter_loads"]
            / max(1, by["random"]["adapter_loads"]), 3),
        "affinity_beats_random_on_loads": bool(
            by["adapter_affinity"]["adapter_loads"]
            < by["random"]["adapter_loads"]),
        "affinity_hit_rate": round(by["adapter_affinity"]["hit_rate"], 3),
        "random_hit_rate": round(by["random"]["hit_rate"], 3),
        "completed_all": all(r["completed"] > 0 for r in rows),
    }


# ------------------------------------------------------------------
# Prefix-affinity cell: warm radix trees vs adapter locality.
# ------------------------------------------------------------------
def run_prefix_affinity(n_engines: int = 2, quick: bool = True,
                        seed: int = 0):
    """Same-preamble requests under ``prefix_affinity`` vs
    ``adapter_affinity`` routing: prefix keys concentrate each
    preamble group on one replica, so its radix tree (PR 6) stays warm
    and the cluster-wide prefix hit rate rises; adapter-keyed routing
    scatters the groups (adapters are assigned across groups) and the
    trees stay cold. Runs in ``prefix_mode="alora"`` — prefix pages
    are adapter-invariant there (PR 6), so reuse is decided purely by
    *where* a preamble's requests land, which is what this cell
    isolates; in "exact" mode the adapter key would confound it."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import Request
    from repro.models import api
    from repro.serving.cluster import EngineCluster, EngineClusterConfig
    from repro.serving.engine import EngineConfig

    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(max_slots=4, max_len=160, n_lora_slots=4,
                        n_adapters=8, seed=seed, prefix_mode="alora")
    rng = np.random.default_rng(seed)
    n_groups = 4
    per_group = 4 if quick else 8
    preambles = [[int(x) for x in rng.integers(1, 200, 48)]
                 for _ in range(n_groups)]

    def mk_reqs():
        reqs = []
        for g, pre in enumerate(preambles):
            for j in range(per_group):
                suffix = [int(x) for x in rng.integers(200, 250, 8)]
                # Adapters deliberately cut across groups: adapter
                # locality and prefix locality point at different
                # replicas, so the two policies actually diverge.
                reqs.append(Request(
                    input_len=len(pre) + len(suffix), output_len=4,
                    adapter_id=(g + j) % ecfg.n_adapters,
                    prompt=pre + suffix))
        return reqs

    rows = []
    for policy in ("adapter_affinity", "prefix_affinity"):
        rng = np.random.default_rng(seed)      # same suffixes per policy
        cluster = EngineCluster(
            cfg, params, ecfg,
            EngineClusterConfig(n_engines=n_engines, policy=policy,
                                seed=seed))
        cluster.warmup()
        handles = [cluster.submit(r) for r in mk_reqs()]
        cluster.drain()
        merged, _ = cluster.metrics()
        sg = merged.sched_stats
        rows.append({
            "policy": policy, "n_engines": n_engines,
            "completed": sum(h.done for h in handles),
            "prefix_hit_rate": sg.get("prefix_hit_rate", 0.0),
            "prefix_hit_tokens": sg.get("prefix_hit_tokens", 0),
            "adapter_loads": merged.cache_stats["misses"],
            "routed": cluster.routed.tolist(),
        })
    return rows


def validate_prefix_affinity(rows) -> dict:
    by = {r["policy"]: r for r in rows}
    return {
        "prefix_hit_rate_prefix_affinity": round(
            by["prefix_affinity"]["prefix_hit_rate"], 3),
        "prefix_hit_rate_adapter_affinity": round(
            by["adapter_affinity"]["prefix_hit_rate"], 3),
        "prefix_affinity_warms_trees": bool(
            by["prefix_affinity"]["prefix_hit_rate"]
            >= by["adapter_affinity"]["prefix_hit_rate"]),
        "completed_all": all(r["completed"] > 0 for r in rows),
    }


if __name__ == "__main__":
    import argparse

    from .common import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-engine", action="store_true",
                    help="drive N real JAX engine replicas instead of "
                         "the DES cluster")
    ap.add_argument("--prefix", action="store_true",
                    help="prefix_affinity vs adapter_affinity warm-tree "
                         "cell (real engines)")
    ap.add_argument("--n-engines", type=int, default=2)
    ap.add_argument("--system", default="chameleon")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write {name, paper_ref, rows, validated} "
                         "to PATH (CI schema)")
    args = ap.parse_args()
    if args.prefix:
        rows = run_prefix_affinity(n_engines=args.n_engines,
                                   quick=not args.full)
        validated = validate_prefix_affinity(rows)
        variant = f"{NAME}_prefix_affinity"
    elif args.real_engine:
        rows = run_real_engine(n_engines=args.n_engines,
                               quick=not args.full, system=args.system)
        validated = validate_real_engine(rows)
        variant = f"{NAME}_real_engine"
    else:
        rows = run(quick=not args.full)
        validated = validate(rows)
        variant = NAME
    for r in rows:
        print({k: (round(v, 3) if isinstance(v, float) else v)
               for k, v in r.items()})
    print(validated)
    if args.json:
        print("wrote", emit_json(args.json, variant, PAPER_REF, rows,
                                 validated))
